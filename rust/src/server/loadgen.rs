//! Open-loop load-generation engine for the wire protocol — the library
//! half of `examples/loadgen.rs`, shared with `tests/loadgen_smoke.rs`.
//!
//! The plan is built up front and is *fully deterministic from the seed*:
//! [`schedule`] turns a [`LoadProfile`] into a concrete list of
//! [`PlannedRequest`]s — Poisson arrival times at the target RPS, Zipf
//! model popularity over the profile's model list, and a per-request mix
//! of solver/NFE/batch-size/deadline/framing drawn from a second RNG
//! stream. Two calls with the same profile produce byte-identical plans,
//! so a load experiment is reproducible from `--seed` alone.
//!
//! [`run`] then replays the plan against a live server in open-loop
//! fashion: requests are dealt round-robin across a fixed pool of
//! connections, and each connection thread sleeps until a request's
//! scheduled arrival time before sending it — arrivals do not wait for
//! earlier replies, except that one connection carries one request at a
//! time (the wire protocol's ordering contract), so the pool size bounds
//! how many replies may be outstanding. With enough connections the
//! offered load tracks the schedule even when the server is slow.
//!
//! Replies are classified client-side into the same four lifecycle terms
//! the server counts (`completed` / `rejected` / `expired` / `failed`),
//! and [`reconcile`] cross-checks the client tallies against the live
//! `{"cmd":"stats"}` wire — global and `per_model` — so a loadgen run is
//! also an end-to-end audit of the server's accounting. Reconciliation
//! assumes the generator is the server's only client.

use std::collections::BTreeMap;
use std::net::SocketAddr;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::stats::LatencyHistogram;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::sync::lock_recover;

use super::Client;

/// XOR tag deriving the request-mix RNG stream from the arrival stream's
/// seed, so the two draws cannot alias.
const MIX_STREAM_TAG: u64 = 0xD1FF_0517;

/// What traffic to offer. Every field participates in the deterministic
/// plan; [`LoadProfile::default`] is a sane mixed workload against the
/// artifact-free `gmm2d_oracle` model.
#[derive(Clone, Debug)]
pub struct LoadProfile {
    /// Master seed: same seed + same profile ⇒ identical plan.
    pub seed: u64,
    /// Target offered load, requests per second (Poisson arrivals).
    pub rps: f64,
    /// Length of the arrival window; requests are scheduled in `[0, dur)`.
    pub duration: Duration,
    /// Models to spread traffic over, most-popular first (Zipf rank 1..).
    pub models: Vec<String>,
    /// Zipf exponent for model popularity (0 = uniform).
    pub zipf_s: f64,
    /// Fraction of requests that carry a `deadline_ms`.
    pub deadline_share: f64,
    /// Tight/loose deadline values; deadline-carrying requests split
    /// evenly between the two.
    pub tight_ms: u64,
    pub loose_ms: u64,
    /// Fraction of requests asking for `return_samples`.
    pub samples_share: f64,
    /// Of the `return_samples` requests, fraction using `"frame":"bin"`.
    pub bin_share: f64,
    /// NFE choices, drawn uniformly.
    pub nfes: Vec<usize>,
    /// Batch-size (`n`) choices, drawn uniformly.
    pub n_choices: Vec<usize>,
    /// Solver names (wire spelling), drawn uniformly.
    pub solvers: Vec<String>,
}

impl Default for LoadProfile {
    fn default() -> LoadProfile {
        LoadProfile {
            seed: 0,
            rps: 200.0,
            duration: Duration::from_secs(1),
            models: vec!["gmm2d_oracle".to_string()],
            zipf_s: 1.1,
            deadline_share: 0.5,
            tight_ms: 50,
            loose_ms: 2000,
            samples_share: 0.5,
            bin_share: 0.5,
            nfes: vec![5, 10, 20],
            n_choices: vec![4, 16, 64],
            solvers: vec!["tab3".to_string(), "ddim".to_string(), "tab2".to_string()],
        }
    }
}

/// One concrete request in the plan: when to send it and exactly what to
/// send. `bin` implies `return_samples` (a bin frame with no payload
/// degrades server-side, so the plan never produces that combination).
#[derive(Clone, Debug, PartialEq)]
pub struct PlannedRequest {
    /// Scheduled arrival, relative to the start of the run.
    pub at: Duration,
    pub model: String,
    pub solver: String,
    pub nfe: usize,
    pub n: usize,
    pub seed: u64,
    pub deadline_ms: Option<u64>,
    pub return_samples: bool,
    pub bin: bool,
}

impl PlannedRequest {
    /// The wire line for this request.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("model", Json::str(&self.model)),
            ("solver", Json::str(&self.solver)),
            ("nfe", Json::num(self.nfe as f64)),
            ("n", Json::num(self.n as f64)),
            ("seed", Json::uint(self.seed)),
        ];
        if let Some(ms) = self.deadline_ms {
            pairs.push(("deadline_ms", Json::num(ms as f64)));
        }
        if self.return_samples {
            pairs.push(("return_samples", Json::Bool(true)));
        }
        if self.bin {
            pairs.push(("frame", Json::str("bin")));
        }
        Json::obj(pairs)
    }
}

/// Zipf CDF over ranks 1..=n with exponent s (s = 0 ⇒ uniform). The CDF
/// is precomputed once; a uniform draw picks the model index.
fn zipf_cdf(n: usize, s: f64) -> Vec<f64> {
    let weights: Vec<f64> = (1..=n).map(|r| (r as f64).powf(-s)).collect();
    let total: f64 = weights.iter().sum();
    let mut acc = 0.0;
    weights
        .iter()
        .map(|w| {
            acc += w / total;
            acc
        })
        .collect()
}

/// Build the deterministic request plan. Arrival times come from one RNG
/// stream (exponential inter-arrival gaps at `rps`), the per-request mix
/// from a second independent stream, so e.g. adding a model to the mix
/// does not shift the arrival schedule.
pub fn schedule(profile: &LoadProfile) -> Vec<PlannedRequest> {
    assert!(!profile.models.is_empty(), "profile needs at least one model");
    assert!(!profile.nfes.is_empty() && !profile.n_choices.is_empty());
    assert!(!profile.solvers.is_empty());
    assert!(profile.rps > 0.0, "rps must be positive");
    let mut arrivals = Rng::new(profile.seed);
    let mut mix = Rng::new(profile.seed ^ MIX_STREAM_TAG);
    let cdf = zipf_cdf(profile.models.len(), profile.zipf_s);
    let horizon = profile.duration.as_secs_f64();
    let mut plan = Vec::new();
    let mut t = 0.0f64;
    loop {
        // Exponential gap; uniform() < 1 so the log argument is positive.
        t += -(1.0 - arrivals.uniform()).ln() / profile.rps;
        if t >= horizon {
            return plan;
        }
        let u = mix.uniform();
        let model_idx = cdf.iter().position(|&c| u < c).unwrap_or(cdf.len() - 1);
        let deadline_ms = if mix.uniform() < profile.deadline_share {
            Some(if mix.uniform() < 0.5 { profile.tight_ms } else { profile.loose_ms })
        } else {
            None
        };
        let return_samples = mix.uniform() < profile.samples_share;
        let bin = return_samples && mix.uniform() < profile.bin_share;
        plan.push(PlannedRequest {
            at: Duration::from_secs_f64(t),
            model: profile.models[model_idx].clone(),
            solver: profile.solvers[mix.below(profile.solvers.len())].clone(),
            nfe: profile.nfes[mix.below(profile.nfes.len())],
            n: profile.n_choices[mix.below(profile.n_choices.len())],
            seed: mix.next_u64(),
            deadline_ms,
            return_samples,
            bin,
        });
    }
}

/// Client-side lifecycle tallies, mirroring the server's four-term
/// balance plus the deadline split.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Tally {
    pub sent: u64,
    pub completed: u64,
    pub rejected: u64,
    pub expired: u64,
    pub failed: u64,
    /// Completed requests that carried a deadline.
    pub deadline_hit: u64,
    /// Requests dropped because their deadline fired (== `expired`).
    pub deadline_missed: u64,
}

impl Tally {
    fn add(&mut self, other: &Tally) {
        self.sent += other.sent;
        self.completed += other.completed;
        self.rejected += other.rejected;
        self.expired += other.expired;
        self.failed += other.failed;
        self.deadline_hit += other.deadline_hit;
        self.deadline_missed += other.deadline_missed;
    }
}

/// What a [`run`] measured.
#[derive(Clone, Debug)]
pub struct LoadReport {
    pub global: Tally,
    pub per_model: BTreeMap<String, Tally>,
    /// Client-observed request latency (send → full reply), microseconds,
    /// bucketed like the server's histogram.
    pub p50_us: u64,
    pub p99_us: u64,
    pub mean_us: f64,
    /// Wall time from first scheduled send to last reply.
    pub wall: Duration,
}

impl LoadReport {
    /// Completed requests per wall second.
    pub fn throughput_rps(&self) -> f64 {
        if self.wall.as_secs_f64() > 0.0 {
            self.global.completed as f64 / self.wall.as_secs_f64()
        } else {
            0.0
        }
    }

    /// `deadline_hit / (deadline_hit + deadline_missed)`; 1.0 when no
    /// deadline-carrying request resolved either way.
    pub fn deadline_hit_rate(&self) -> f64 {
        let denom = self.global.deadline_hit + self.global.deadline_missed;
        if denom == 0 {
            1.0
        } else {
            self.global.deadline_hit as f64 / denom as f64
        }
    }
}

/// Classify one reply into a lifecycle term. Mirrors the server's
/// accounting (wire doc in `server/mod.rs`): deadline errors are
/// `expired`; every refusal-at-submit text is `rejected`; anything else
/// not-ok is `failed` (contained faults: panics, non-finite output,
/// drain-stranded work).
fn classify(deadline: Option<u64>, ok: bool, error: &str, tally: &mut Tally) {
    if ok {
        tally.completed += 1;
        if deadline.is_some() {
            tally.deadline_hit += 1;
        }
        return;
    }
    if error.contains("deadline exceeded") {
        tally.expired += 1;
        tally.deadline_missed += 1;
    } else if ["overloaded", "unknown model", "unhealthy", "out of range",
               "shutting down", "unknown solver", "unknown grid", "unknown sde",
               "unknown dtype"]
        .iter()
        .any(|s| error.contains(s))
    {
        tally.rejected += 1;
    } else {
        tally.failed += 1;
    }
}

/// Replay the plan against a live server over `conns` connections and
/// collect the report. Blocks until every reply is in.
pub fn run(addr: SocketAddr, profile: &LoadProfile, conns: usize) -> Result<LoadReport> {
    let plan = schedule(profile);
    run_plan(addr, &plan, conns)
}

/// [`run`] over a prebuilt plan (lets tests replay the exact same plan
/// they inspected).
pub fn run_plan(
    addr: SocketAddr,
    plan: &[PlannedRequest],
    conns: usize,
) -> Result<LoadReport> {
    let conns = conns.max(1);
    let hist = LatencyHistogram::default();
    let acc: Mutex<(Tally, BTreeMap<String, Tally>)> = Mutex::new(Default::default());
    let start = Instant::now();
    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::with_capacity(conns);
        for c in 0..conns {
            let hist = &hist;
            let acc = &acc;
            // Round-robin deal: thread c owns plan[c], plan[c+conns], ...
            // Each thread's slice is time-ordered because the plan is.
            let mine: Vec<&PlannedRequest> =
                plan.iter().skip(c).step_by(conns).collect();
            handles.push(scope.spawn(move || -> Result<()> {
                if mine.is_empty() {
                    return Ok(());
                }
                let mut client = Client::connect(addr)
                    .with_context(|| format!("loadgen conn {c}"))?;
                let mut global = Tally::default();
                let mut per_model: BTreeMap<String, Tally> = BTreeMap::new();
                for req in mine {
                    let now = start.elapsed();
                    if req.at > now {
                        std::thread::sleep(req.at - now);
                    }
                    let line = req.to_json();
                    let sent_at = Instant::now();
                    let header = if req.bin {
                        client.call_bin(&line)?.0
                    } else {
                        client.call(&line)?
                    };
                    let us = sent_at.elapsed().as_micros().min(u64::MAX as u128);
                    hist.record(us as u64);
                    let ok = header.get("ok")?.as_bool()?;
                    let error = if ok {
                        String::new()
                    } else {
                        header.get("error")?.as_str()?.to_string()
                    };
                    for t in [&mut global, per_model.entry(req.model.clone()).or_default()]
                    {
                        t.sent += 1;
                        classify(req.deadline_ms, ok, &error, t);
                    }
                }
                let mut locked = lock_recover(acc);
                locked.0.add(&global);
                for (m, t) in &per_model {
                    locked.1.entry(m.clone()).or_default().add(t);
                }
                Ok(())
            }));
        }
        for h in handles {
            h.join().expect("loadgen thread panicked")?;
        }
        Ok(())
    })?;
    let wall = start.elapsed();
    let (global, per_model) = lock_recover(&acc).clone();
    Ok(LoadReport {
        global,
        per_model,
        p50_us: hist.quantile(0.5),
        p99_us: hist.quantile(0.99),
        mean_us: hist.mean(),
        wall,
    })
}

/// Fetch the live `{"cmd":"stats"}` object from the server.
pub fn fetch_stats(addr: SocketAddr) -> Result<Json> {
    let mut client = Client::connect(addr)?;
    client.call(&Json::obj(vec![("cmd", Json::str("stats"))]))
}

fn stat_u64(v: &Json, key: &str) -> Result<u64> {
    Ok(v.get(key)?.as_f64()? as u64)
}

fn check(scope: &str, key: &str, client: u64, server: u64) -> Result<()> {
    if client != server {
        bail!("{scope}: client {key}={client} but server reports {server}");
    }
    Ok(())
}

/// `err_adjust` is the number of this scope's requests that the ROUTER
/// answered itself with an `upstream unavailable` error (zero for a direct
/// server): those requests never reached a worker, so they are missing
/// from the aggregated worker counters but present in the client's `sent`
/// (classified `failed`). Adding them to the server side of `requests` and
/// of the lifecycle sum makes both tiers reconcile with one equation.
fn reconcile_tally(scope: &str, t: &Tally, v: &Json, err_adjust: u64) -> Result<()> {
    check(scope, "requests", t.sent, stat_u64(v, "requests")? + err_adjust)?;
    check(scope, "completed", t.completed, stat_u64(v, "completed")?)?;
    check(scope, "expired", t.expired, stat_u64(v, "expired")?)?;
    check(scope, "deadline_hit", t.deadline_hit, stat_u64(v, "deadline_hit")?)?;
    check(
        scope,
        "deadline_missed",
        t.deadline_missed,
        stat_u64(v, "deadline_missed")?,
    )?;
    // rejected/failed cannot be attributed per-scope symmetrically when
    // refusals land only in the global counters (unknown model, global
    // overload), so reconcile their SUM through the 4-term balance:
    // server requests == completed + rejected + expired + failed must
    // match the client's same sum.
    let client_sum = t.completed + t.rejected + t.expired + t.failed;
    let server_sum = stat_u64(v, "completed")?
        + stat_u64(v, "rejected")?
        + stat_u64(v, "expired")?
        + stat_u64(v, "failed")?
        + err_adjust;
    check(scope, "lifecycle sum", client_sum, server_sum)?;
    Ok(())
}

/// Router-answered errors for one per-model scope, from the `"router"`
/// object's `per_model_errors` map (0 when absent or direct).
fn router_model_errors(router: Option<&Json>, model: &str) -> Result<u64> {
    match router.and_then(|r| r.opt("per_model_errors")).and_then(|pm| pm.opt(model)) {
        Some(v) => Ok(v.as_f64()? as u64),
        None => Ok(0),
    }
}

/// Cross-check a client-side [`LoadReport`] against the server's stats
/// wire, global and per model. Assumes the loadgen was the only client
/// (any other traffic shows up as a mismatch) and that every model in the
/// plan is registered on the server — an unknown model is refused before
/// a stats shard exists for it, so its per-model entry cannot reconcile.
///
/// Works identically against a worker and against a router: a router
/// stats reply carries a `"router"` object, whose `upstream_errors` /
/// `per_model_errors` bridge the gap between what the client sent and
/// what the workers saw (see [`reconcile_tally`]), and whose own balance
/// `requests == forwarded + upstream_errors + in_flight` is checked too.
pub fn reconcile(report: &LoadReport, stats: &Json) -> Result<()> {
    let router = stats.opt("router");
    let global_adjust = match router {
        Some(r) => stat_u64(r, "upstream_errors")?,
        None => 0,
    };
    reconcile_tally("global", &report.global, stats, global_adjust)?;
    let per_model = stats.get("per_model")?;
    for (model, tally) in &report.per_model {
        let adjust = router_model_errors(router, model)?;
        match per_model.opt(model) {
            Some(entry) => {
                reconcile_tally(&format!("per_model.{model}"), tally, entry, adjust)?
            }
            // Every request for this model died at the router (worker down
            // before any was forwarded): no worker shard exists, and the
            // router's error count must account for the whole tally.
            None if adjust == tally.sent && tally.failed == tally.sent => {}
            None => bail!(
                "server stats missing per_model entry '{model}' \
                 (router errors cover {adjust} of {} sent)",
                tally.sent
            ),
        }
    }
    if let Some(r) = router {
        let requests = stat_u64(r, "requests")?;
        let forwarded = stat_u64(r, "forwarded")?;
        let upstream_errors = stat_u64(r, "upstream_errors")?;
        let in_flight = stat_u64(r, "in_flight")?;
        if requests != forwarded + upstream_errors + in_flight {
            bail!(
                "router balance violated: requests {requests} != forwarded {forwarded} \
                 + upstream_errors {upstream_errors} + in_flight {in_flight}"
            );
        }
    }
    Ok(())
}

/// Human-readable report block (example output; tests assert on fields).
pub fn format_report(report: &LoadReport) -> String {
    let g = &report.global;
    let mut s = String::new();
    s.push_str(&format!(
        "sent {} | completed {} rejected {} expired {} failed {}\n",
        g.sent, g.completed, g.rejected, g.expired, g.failed
    ));
    s.push_str(&format!(
        "deadline hit rate {:.3} ({} hit / {} missed)\n",
        report.deadline_hit_rate(),
        g.deadline_hit,
        g.deadline_missed
    ));
    s.push_str(&format!(
        "latency p50 {} us  p99 {} us  mean {:.0} us\n",
        report.p50_us, report.p99_us, report.mean_us
    ));
    s.push_str(&format!(
        "throughput {:.1} req/s over {:.2}s wall\n",
        report.throughput_rps(),
        report.wall.as_secs_f64()
    ));
    for (model, t) in &report.per_model {
        s.push_str(&format!(
            "  {model}: sent {} completed {} rejected {} expired {} failed {}\n",
            t.sent, t.completed, t.rejected, t.expired, t.failed
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profile() -> LoadProfile {
        LoadProfile {
            seed: 42,
            rps: 500.0,
            duration: Duration::from_secs(2),
            models: vec!["a".into(), "b".into(), "c".into()],
            ..Default::default()
        }
    }

    #[test]
    fn schedule_is_deterministic_per_seed() {
        let p = profile();
        assert_eq!(schedule(&p), schedule(&p));
        let mut p2 = profile();
        p2.seed = 43;
        assert_ne!(schedule(&p), schedule(&p2));
    }

    #[test]
    fn arrivals_are_monotone_within_the_horizon() {
        let p = profile();
        let plan = schedule(&p);
        // ~rps * duration arrivals, within loose Poisson slack.
        assert!(plan.len() > 800 && plan.len() < 1200, "{}", plan.len());
        for w in plan.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
        assert!(plan.last().unwrap().at < p.duration);
    }

    #[test]
    fn zipf_ranks_models_by_popularity() {
        let plan = schedule(&profile());
        let count = |m: &str| plan.iter().filter(|r| r.model == m).count();
        let (a, b, c) = (count("a"), count("b"), count("c"));
        assert_eq!(a + b + c, plan.len());
        assert!(a > b && b > c, "zipf order violated: a={a} b={b} c={c}");
    }

    #[test]
    fn bin_frames_always_carry_samples() {
        let plan = schedule(&profile());
        assert!(plan.iter().any(|r| r.bin), "mix never produced a bin frame");
        assert!(plan.iter().any(|r| r.deadline_ms.is_some()));
        assert!(plan.iter().any(|r| r.deadline_ms.is_none()));
        for r in &plan {
            assert!(!r.bin || r.return_samples);
        }
    }

    #[test]
    fn mix_is_independent_of_the_arrival_stream() {
        // Same seed, different rps: the request mix (model/solver/nfe/...)
        // must be identical request-for-request; only `at` changes.
        let p = profile();
        let mut faster = profile();
        faster.rps = 1000.0;
        let a = schedule(&p);
        let b = schedule(&faster);
        let n = a.len().min(b.len());
        for i in 0..n {
            let (mut x, mut y) = (a[i].clone(), b[i].clone());
            x.at = Duration::ZERO;
            y.at = Duration::ZERO;
            assert_eq!(x, y, "mix diverged at request {i}");
        }
    }

    #[test]
    fn classify_matches_server_accounting() {
        let mut t = Tally::default();
        classify(Some(50), true, "", &mut t);
        classify(None, true, "", &mut t);
        classify(Some(50), false, "deadline exceeded after 50ms", &mut t);
        classify(None, false, "coordinator overloaded (4096 in flight)", &mut t);
        classify(None, false, "unknown model 'nope'", &mut t);
        classify(None, false, "model eval panicked", &mut t);
        assert_eq!(t.completed, 2);
        assert_eq!(t.deadline_hit, 1);
        assert_eq!(t.expired, 1);
        assert_eq!(t.deadline_missed, 1);
        assert_eq!(t.rejected, 2);
        assert_eq!(t.failed, 1);
    }

    #[test]
    fn zipf_cdf_ends_at_one() {
        for (n, s) in [(1, 1.0), (3, 0.0), (8, 1.3)] {
            let cdf = zipf_cdf(n, s);
            assert_eq!(cdf.len(), n);
            assert!((cdf[n - 1] - 1.0).abs() < 1e-12);
            for w in cdf.windows(2) {
                assert!(w[0] < w[1]);
            }
        }
    }
}
