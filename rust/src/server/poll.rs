//! Tiny readiness layer over Linux `epoll`, declared straight against the
//! C ABI (the offline registry has only `anyhow`, so no `libc`/`mio`).
//! Everything the event-loop front end needs and nothing more:
//!
//! - [`Poller`]: level-triggered epoll instance — register/modify/
//!   deregister a raw fd under a `u64` token, then [`Poller::wait`] for
//!   readiness events with a timeout (the timeout doubles as the front
//!   end's stall-sweep tick).
//! - [`Waker`] / [`waker_pair`]: cross-thread wakeup for a parked
//!   `epoll_wait`, built on a non-blocking `UnixStream` pair instead of an
//!   `eventfd` FFI — the read end registers in the poller like any socket,
//!   and [`drain_waker`] resets it.
//! - [`raise_nofile_limit`]: best-effort `RLIMIT_NOFILE` bump so the
//!   many-connection capacity test can actually open its sockets.
//!
//! The syscall surface is three functions (`epoll_create1`, `epoll_ctl`,
//! `epoll_wait`) plus `getrlimit`/`setrlimit`, all resolved from the libc
//! the binary links anyway. `std::io::Error::last_os_error()` reads errno,
//! and `OwnedFd` owns the epoll fd, so there is no hand-rolled resource
//! management. Level-triggered mode is deliberate: spurious or stale
//! events degrade into a `WouldBlock` read/write, never a lost one, which
//! keeps the connection state machines simple to reason about.

use std::ffi::c_int;
use std::io::{self, Read, Write};
use std::os::fd::{AsRawFd, FromRawFd, OwnedFd, RawFd};
use std::os::unix::net::UnixStream;
use std::sync::Arc;
use std::time::Duration;

const EPOLL_CLOEXEC: c_int = 0o2000000;
const EPOLL_CTL_ADD: c_int = 1;
const EPOLL_CTL_DEL: c_int = 2;
const EPOLL_CTL_MOD: c_int = 3;
const EPOLLIN: u32 = 0x001;
const EPOLLOUT: u32 = 0x004;
const EPOLLERR: u32 = 0x008;
const EPOLLHUP: u32 = 0x010;

/// Kernel event record. x86_64 packs this struct (a 32-bit `events` word
/// directly followed by the 64-bit payload); other architectures use
/// natural C alignment. Fields are only ever copied out by value — taking
/// a reference into a packed struct would be UB-adjacent, so don't.
#[repr(C)]
#[cfg_attr(target_arch = "x86_64", repr(packed))]
#[derive(Clone, Copy)]
struct EpollEvent {
    events: u32,
    data: u64,
}

extern "C" {
    fn epoll_create1(flags: c_int) -> c_int;
    fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
    fn epoll_wait(epfd: c_int, events: *mut EpollEvent, maxevents: c_int, timeout: c_int)
        -> c_int;
    fn getrlimit(resource: c_int, rlim: *mut RLimit) -> c_int;
    fn setrlimit(resource: c_int, rlim: *const RLimit) -> c_int;
}

/// What a registration wants to hear about. Error/hangup conditions are
/// always reported by the kernel regardless of interest.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct Interest {
    pub read: bool,
    pub write: bool,
}

impl Interest {
    pub const READ: Interest = Interest { read: true, write: false };

    fn bits(self) -> u32 {
        let mut e = 0;
        if self.read {
            e |= EPOLLIN;
        }
        if self.write {
            e |= EPOLLOUT;
        }
        e
    }
}

/// One readiness report. `hangup` covers both `EPOLLHUP` and `EPOLLERR`;
/// the caller's correct reaction to either is to attempt the pending I/O
/// and let the resulting error/EOF drive the close.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
    pub hangup: bool,
}

const MAX_EVENTS: usize = 256;

/// Level-triggered epoll instance.
pub struct Poller {
    ep: OwnedFd,
}

impl Poller {
    pub fn new() -> io::Result<Poller> {
        let fd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
        if fd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Poller { ep: unsafe { OwnedFd::from_raw_fd(fd) } })
    }

    fn ctl(&self, op: c_int, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        let mut ev = EpollEvent { events, data: token };
        let rc = unsafe { epoll_ctl(self.ep.as_raw_fd(), op, fd, &mut ev) };
        if rc < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    pub fn register(&self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        self.ctl(EPOLL_CTL_ADD, fd, interest.bits(), token)
    }

    pub fn modify(&self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd, interest.bits(), token)
    }

    pub fn deregister(&self, fd: RawFd) -> io::Result<()> {
        // The event argument must be non-null for portability (pre-2.6.9
        // kernels faulted on NULL); the kernel ignores its contents on DEL.
        self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
    }

    /// Collect ready events into `out` (appending). `None` blocks forever;
    /// `Some(d)` waits at most `d` (rounded up to a millisecond so a short
    /// positive timeout cannot busy-spin). Returns after one wait, possibly
    /// with zero events (timeout).
    pub fn wait(&self, out: &mut Vec<Event>, timeout: Option<Duration>) -> io::Result<()> {
        let ms: c_int = match timeout {
            None => -1,
            Some(d) => {
                let ms = d.as_millis();
                if ms == 0 && !d.is_zero() {
                    1
                } else {
                    ms.min(i32::MAX as u128) as c_int
                }
            }
        };
        let mut buf = [EpollEvent { events: 0, data: 0 }; MAX_EVENTS];
        loop {
            let n = unsafe {
                epoll_wait(self.ep.as_raw_fd(), buf.as_mut_ptr(), MAX_EVENTS as c_int, ms)
            };
            if n < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    continue;
                }
                return Err(e);
            }
            for ev in buf.iter().take(n as usize) {
                // Copy the packed fields out by value before use.
                let bits = { ev.events };
                let token = { ev.data };
                out.push(Event {
                    token,
                    readable: bits & EPOLLIN != 0,
                    writable: bits & EPOLLOUT != 0,
                    hangup: bits & (EPOLLERR | EPOLLHUP) != 0,
                });
            }
            return Ok(());
        }
    }
}

/// Cross-thread wakeup handle: one byte down a non-blocking socket pair.
/// `WouldBlock` on a full pipe is fine — a wakeup is already pending, and
/// one pending wakeup is all a level-triggered poller needs.
#[derive(Clone)]
pub struct Waker {
    tx: Arc<UnixStream>,
}

impl Waker {
    pub fn wake(&self) {
        let _ = (&*self.tx).write(&[1u8]);
    }
}

/// Build a waker and the stream its target thread registers for reads.
pub fn waker_pair() -> io::Result<(Waker, UnixStream)> {
    let (rx, tx) = UnixStream::pair()?;
    rx.set_nonblocking(true)?;
    tx.set_nonblocking(true)?;
    Ok((Waker { tx: Arc::new(tx) }, rx))
}

/// Drain every pending wakeup byte so the (level-triggered) readable state
/// clears until the next `wake`.
pub fn drain_waker(rx: &UnixStream) {
    let mut buf = [0u8; 64];
    while matches!((&*rx).read(&mut buf), Ok(n) if n > 0) {}
}

#[repr(C)]
struct RLimit {
    rlim_cur: u64,
    rlim_max: u64,
}

const RLIMIT_NOFILE: c_int = 7;

/// Best-effort raise of the soft open-file limit to at least `want`
/// (capped by the hard limit). Returns the soft limit now in effect —
/// callers that need thousands of sockets (the capacity test) check the
/// return and skip rather than fail when the environment refuses.
pub fn raise_nofile_limit(want: u64) -> u64 {
    let mut r = RLimit { rlim_cur: 0, rlim_max: 0 };
    if unsafe { getrlimit(RLIMIT_NOFILE, &mut r) } != 0 {
        return 0;
    }
    if r.rlim_cur >= want {
        return r.rlim_cur;
    }
    let bumped = RLimit { rlim_cur: want.min(r.rlim_max), rlim_max: r.rlim_max };
    if unsafe { setrlimit(RLIMIT_NOFILE, &bumped) } == 0 {
        bumped.rlim_cur
    } else {
        r.rlim_cur
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn readable_event_fires_with_token_and_timeout_is_quiet() {
        let poller = Poller::new().unwrap();
        let (a, b) = UnixStream::pair().unwrap();
        a.set_nonblocking(true).unwrap();
        poller.register(a.as_raw_fd(), 42, Interest::READ).unwrap();
        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_millis(0))).unwrap();
        assert!(events.is_empty(), "no data yet, wait must time out clean");
        (&b).write_all(b"x").unwrap();
        poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].token, 42);
        assert!(events[0].readable);
        // Level-triggered: the event repeats until the data is consumed.
        events.clear();
        poller.wait(&mut events, Some(Duration::from_millis(50))).unwrap();
        assert_eq!(events.len(), 1, "level-triggered events must persist");
        let mut buf = [0u8; 8];
        assert_eq!((&a).read(&mut buf).unwrap(), 1);
        events.clear();
        poller.wait(&mut events, Some(Duration::from_millis(0))).unwrap();
        assert!(events.is_empty(), "consumed data clears the readable state");
    }

    #[test]
    fn writability_and_interest_changes() {
        let poller = Poller::new().unwrap();
        let (a, _b) = UnixStream::pair().unwrap();
        a.set_nonblocking(true).unwrap();
        poller
            .register(a.as_raw_fd(), 7, Interest { read: false, write: true })
            .unwrap();
        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert!(events.iter().any(|e| e.token == 7 && e.writable), "{events:?}");
        // Dropping write interest silences the (still-writable) socket.
        poller.modify(a.as_raw_fd(), 7, Interest { read: true, write: false }).unwrap();
        events.clear();
        poller.wait(&mut events, Some(Duration::from_millis(0))).unwrap();
        assert!(events.is_empty());
        poller.deregister(a.as_raw_fd()).unwrap();
    }

    #[test]
    fn waker_wakes_and_drains() {
        let poller = Poller::new().unwrap();
        let (waker, rx) = waker_pair().unwrap();
        poller.register(rx.as_raw_fd(), 1, Interest::READ).unwrap();
        let mut events = Vec::new();
        poller.wait(&mut events, Some(Duration::from_millis(0))).unwrap();
        assert!(events.is_empty());
        // Wake from another thread, as the responder hooks do.
        let w2 = waker.clone();
        std::thread::spawn(move || w2.wake()).join().unwrap();
        poller.wait(&mut events, Some(Duration::from_secs(5))).unwrap();
        assert!(events.iter().any(|e| e.token == 1 && e.readable));
        drain_waker(&rx);
        events.clear();
        poller.wait(&mut events, Some(Duration::from_millis(0))).unwrap();
        assert!(events.is_empty(), "drained waker must go quiet");
        // Repeated wakes without a drain never error (full pipe is fine).
        for _ in 0..100_000 {
            waker.wake();
        }
    }

    #[test]
    fn nofile_limit_is_reported() {
        let cur = raise_nofile_limit(64);
        assert!(cur >= 64, "any sane environment grants 64 fds (got {cur})");
    }
}
