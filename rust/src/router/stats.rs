//! Router-side counters and the stats/health/models fan-in merge.
//!
//! The router keeps its own small counter set (plain `u64`s — the event
//! loop is single-threaded, so no atomics) and answers `{"cmd":"stats"}` /
//! `{"cmd":"health"}` / `{"cmd":"models"}` by fanning the command out to
//! every reachable worker and merging the replies into ONE object with the
//! worker wire schema, so existing clients (loadgen's `reconcile`, the
//! `nc` one-liners in the Makefile) work unchanged against the router.
//!
//! Merge rules, per key class (see the wire doc in `server/mod.rs`):
//!
//! * lifecycle / volume counters — SUMMED across workers,
//! * `max_occupancy`, `p50_us`, `p99_us` — MAX (a documented
//!   approximation for the percentiles: the true merged quantile needs
//!   the histograms, which the wire does not carry; max is the
//!   conservative bound),
//! * `eval_occupancy` — recomputed from the summed numerator/denominator
//!   (`sched_eval_requests` / `sched_evals`), never averaged,
//! * `mean_us` — weighted by each worker's `requests`,
//! * `per_model` — unioned (each model lives on one worker, so "union"
//!   is normally disjoint; after a re-home both shards contribute and the
//!   same rules merge the two partial rows),
//! * plus a `"router"` object carrying the router's own counters — these
//!   are deliberately OUTSIDE the worker key set so the worker-level
//!   4-term balance stays checkable and the router's own balance
//!   (`requests == forwarded + upstream_errors + in_flight`) is separate.

use std::collections::{BTreeMap, BTreeSet};

use crate::util::json::Json;

/// Per-worker slice of the router's own counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct WorkerCounters {
    /// Submits enqueued toward this worker.
    pub routed: u64,
    /// Replies relayed back from this worker.
    pub forwarded: u64,
    /// Submits failed by this worker's death or connect failure.
    pub upstream_errors: u64,
}

/// The router's own counters. Owned by the event-loop thread.
#[derive(Clone, Debug, Default)]
pub struct RouterStats {
    /// Submit lines accepted for routing (the router-level "requests").
    pub requests: u64,
    /// Upstream replies relayed toward a client (counted even when the
    /// client vanished before the reply arrived — the work was done).
    pub forwarded: u64,
    /// Submits answered with an `upstream unavailable` error, either
    /// immediately (no healthy worker) or when a worker died mid-request.
    pub upstream_errors: u64,
    /// Fan-out commands handled (stats/health/models).
    pub cmds: u64,
    /// Client lines that failed to parse (answered locally with an error).
    pub bad_lines: u64,
    pub per_worker: Vec<WorkerCounters>,
    /// Per-model attribution of `upstream_errors`, for the per_model half
    /// of loadgen's reconciliation.
    pub per_model_errors: BTreeMap<String, u64>,
}

impl RouterStats {
    pub fn new(workers: usize) -> RouterStats {
        RouterStats { per_worker: vec![WorkerCounters::default(); workers], ..Default::default() }
    }
}

/// What the merge needs to know about each worker beyond its reply.
#[derive(Clone, Debug)]
pub struct WorkerView {
    /// The upstream address as configured — also the rendezvous identity.
    pub addr: String,
    /// At least one live pooled connection (or none attempted yet and the
    /// breaker closed). A worker whose reply slot is `None` in a fan-out
    /// was unreachable for THAT command regardless of this flag.
    pub up: bool,
}

fn num(v: &Json) -> f64 {
    v.as_f64().unwrap_or(0.0)
}

fn key_union<'a>(objs: &[&'a BTreeMap<String, Json>]) -> BTreeSet<&'a str> {
    objs.iter().flat_map(|o| o.keys().map(String::as_str)).collect()
}

fn sum_key(objs: &[&BTreeMap<String, Json>], key: &str) -> f64 {
    objs.iter().filter_map(|o| o.get(key)).map(num).sum()
}

/// Merge one stats-shaped counter object (the global reply or one
/// `per_model` entry) across workers, applying the per-key-class rules
/// from the module doc. Unknown keys default to SUM, so a future worker
/// counter aggregates sensibly without touching the router.
fn merge_counters(objs: &[&BTreeMap<String, Json>]) -> BTreeMap<String, Json> {
    let mut out = BTreeMap::new();
    for key in key_union(objs) {
        let merged = match key {
            "ok" | "per_model" | "eval_occupancy" | "mean_us" => continue,
            "max_occupancy" | "p50_us" | "p99_us" => {
                objs.iter().filter_map(|o| o.get(key)).map(num).fold(0.0, f64::max)
            }
            _ => sum_key(objs, key),
        };
        out.insert(key.to_string(), Json::num(merged));
    }
    if objs.iter().any(|o| o.contains_key("eval_occupancy")) {
        let evals = sum_key(objs, "sched_evals");
        let reqs = sum_key(objs, "sched_eval_requests");
        let occ = if evals > 0.0 { reqs / evals } else { 0.0 };
        out.insert("eval_occupancy".to_string(), Json::num(occ));
    }
    if objs.iter().any(|o| o.contains_key("mean_us")) {
        let total = sum_key(objs, "requests");
        let weighted: f64 =
            objs.iter().map(|o| num2(o, "mean_us") * num2(o, "requests")).sum();
        let mean = if total > 0.0 { weighted / total } else { 0.0 };
        out.insert("mean_us".to_string(), Json::num(mean));
    }
    out
}

fn num2(obj: &BTreeMap<String, Json>, key: &str) -> f64 {
    obj.get(key).map(num).unwrap_or(0.0)
}

/// The `"router"` object embedded in the merged stats reply.
pub fn router_obj(rs: &RouterStats, views: &[WorkerView]) -> Json {
    let per_worker: BTreeMap<String, Json> = views
        .iter()
        .zip(&rs.per_worker)
        .map(|(view, w)| {
            (
                view.addr.clone(),
                Json::obj(vec![
                    ("up", Json::Bool(view.up)),
                    ("routed", Json::uint(w.routed)),
                    ("forwarded", Json::uint(w.forwarded)),
                    ("upstream_errors", Json::uint(w.upstream_errors)),
                ]),
            )
        })
        .collect();
    let per_model_errors: BTreeMap<String, Json> =
        rs.per_model_errors.iter().map(|(m, &n)| (m.clone(), Json::uint(n))).collect();
    // Saturating: if a future edit ever breaks the accounting invariant
    // (requests >= forwarded + upstream_errors), a stats command must
    // report a visibly wrong number, not panic the event loop.
    let in_flight =
        rs.requests.saturating_sub(rs.forwarded).saturating_sub(rs.upstream_errors);
    Json::obj(vec![
        ("workers", Json::uint(views.len() as u64)),
        ("workers_up", Json::uint(views.iter().filter(|v| v.up).count() as u64)),
        ("requests", Json::uint(rs.requests)),
        ("forwarded", Json::uint(rs.forwarded)),
        ("upstream_errors", Json::uint(rs.upstream_errors)),
        ("in_flight", Json::uint(in_flight)),
        ("cmds", Json::uint(rs.cmds)),
        ("bad_lines", Json::uint(rs.bad_lines)),
        ("per_worker", Json::Obj(per_worker)),
        ("per_model_errors", Json::Obj(per_model_errors)),
    ])
}

/// Merge per-worker `{"cmd":"stats"}` replies (slot `None` = that worker
/// was unreachable) into the aggregated reply.
pub fn merge_stats(rs: &RouterStats, views: &[WorkerView], replies: &[Option<Json>]) -> Json {
    let objs: Vec<&BTreeMap<String, Json>> =
        replies.iter().flatten().filter_map(|r| r.as_obj().ok()).collect();
    let mut top = merge_counters(&objs);
    let mut per_model: BTreeMap<String, Vec<&BTreeMap<String, Json>>> = BTreeMap::new();
    for obj in &objs {
        if let Some(Json::Obj(models)) = obj.get("per_model") {
            for (name, entry) in models {
                if let Ok(m) = entry.as_obj() {
                    per_model.entry(name.clone()).or_default().push(m);
                }
            }
        }
    }
    let merged_pm: BTreeMap<String, Json> = per_model
        .into_iter()
        .map(|(name, entries)| (name, Json::Obj(merge_counters(&entries))))
        .collect();
    top.insert("per_model".to_string(), Json::Obj(merged_pm));
    top.insert("ok".to_string(), Json::Bool(true));
    top.insert("router".to_string(), router_obj(rs, views));
    Json::Obj(top)
}

/// Merge `{"cmd":"health"}` replies: `worker_panics` sums, per-model
/// health ANDs (unhealthy anywhere → unhealthy — conservative, since a
/// re-home can move traffic onto any worker carrying the model), and
/// top-level `draining` is true only when every REACHABLE worker is
/// draining. A `"workers"` object breaks all of it out per upstream.
pub fn merge_health(views: &[WorkerView], replies: &[Option<Json>]) -> Json {
    let mut worker_panics: u64 = 0;
    let mut models: BTreeMap<String, bool> = BTreeMap::new();
    let mut workers: BTreeMap<String, Json> = BTreeMap::new();
    let (mut reachable, mut draining_all) = (0u64, true);
    for (view, reply) in views.iter().zip(replies) {
        let obj = reply.as_ref().and_then(|r| r.as_obj().ok());
        let up = obj.is_some();
        let mut draining = false;
        let mut panics = 0u64;
        if let Some(o) = obj {
            reachable += 1;
            draining = o.get("draining").and_then(|d| d.as_bool().ok()).unwrap_or(false);
            panics = o.get("worker_panics").and_then(|p| p.as_u64().ok()).unwrap_or(0);
            draining_all &= draining;
            worker_panics += panics;
            if let Some(Json::Obj(m)) = o.get("models") {
                for (name, healthy) in m {
                    let h = healthy.as_bool().unwrap_or(false);
                    models.entry(name.clone()).and_modify(|cur| *cur &= h).or_insert(h);
                }
            }
        }
        workers.insert(
            view.addr.clone(),
            Json::obj(vec![
                ("up", Json::Bool(up && view.up)),
                ("draining", Json::Bool(draining)),
                ("worker_panics", Json::uint(panics)),
            ]),
        );
    }
    let model_health: BTreeMap<String, Json> =
        models.into_iter().map(|(n, h)| (n, Json::Bool(h))).collect();
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("draining", Json::Bool(reachable > 0 && draining_all)),
        ("worker_panics", Json::uint(worker_panics)),
        ("models", Json::Obj(model_health)),
        ("workers", Json::Obj(workers)),
    ])
}

/// Merge `{"cmd":"models"}` replies: sorted union.
pub fn merge_models(replies: &[Option<Json>]) -> Json {
    let mut names: BTreeSet<String> = BTreeSet::new();
    for reply in replies.iter().flatten() {
        if let Some(Json::Arr(list)) = reply.opt("models") {
            for m in list {
                if let Ok(s) = m.as_str() {
                    names.insert(s.to_string());
                }
            }
        }
    }
    Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("models", Json::Arr(names.into_iter().map(|n| Json::str(&n)).collect())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn worker_stats(requests: f64, p99: f64, mean: f64, models: Vec<(&str, f64)>) -> Json {
        let per_model: BTreeMap<String, Json> = models
            .into_iter()
            .map(|(name, req)| {
                (
                    name.to_string(),
                    Json::obj(vec![
                        ("requests", Json::num(req)),
                        ("completed", Json::num(req)),
                        ("sched_evals", Json::num(2.0)),
                        ("sched_eval_requests", Json::num(req)),
                        ("eval_occupancy", Json::num(req / 2.0)),
                        ("max_occupancy", Json::num(req)),
                    ]),
                )
            })
            .collect();
        Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("requests", Json::num(requests)),
            ("completed", Json::num(requests)),
            ("sched_evals", Json::num(10.0)),
            ("sched_eval_requests", Json::num(requests)),
            ("eval_occupancy", Json::num(requests / 10.0)),
            ("max_occupancy", Json::num(requests)),
            ("p99_us", Json::num(p99)),
            ("mean_us", Json::num(mean)),
            ("per_model", Json::Obj(per_model)),
        ])
    }

    fn views() -> Vec<WorkerView> {
        vec![
            WorkerView { addr: "a:1".into(), up: true },
            WorkerView { addr: "b:2".into(), up: true },
        ]
    }

    #[test]
    fn stats_merge_sums_maxes_and_weights() {
        let mut rs = RouterStats::new(2);
        rs.requests = 30;
        rs.forwarded = 30;
        let a = worker_stats(10.0, 500.0, 100.0, vec![("m0", 10.0)]);
        let b = worker_stats(20.0, 900.0, 400.0, vec![("m1", 20.0)]);
        let merged = merge_stats(&rs, &views(), &[Some(a), Some(b)]);
        assert_eq!(merged.get("requests").unwrap().as_f64().unwrap(), 30.0);
        assert_eq!(merged.get("completed").unwrap().as_f64().unwrap(), 30.0);
        // Percentiles take the max; the mean is request-weighted.
        assert_eq!(merged.get("p99_us").unwrap().as_f64().unwrap(), 900.0);
        let mean = merged.get("mean_us").unwrap().as_f64().unwrap();
        assert!((mean - (10.0 * 100.0 + 20.0 * 400.0) / 30.0).abs() < 1e-9);
        // Occupancy is recomputed from the summed terms, not averaged.
        let occ = merged.get("eval_occupancy").unwrap().as_f64().unwrap();
        assert!((occ - 30.0 / 20.0).abs() < 1e-9);
        // per_model is a disjoint union here.
        let pm = merged.get("per_model").unwrap();
        assert_eq!(pm.get("m0").unwrap().get("requests").unwrap().as_f64().unwrap(), 10.0);
        assert_eq!(pm.get("m1").unwrap().get("requests").unwrap().as_f64().unwrap(), 20.0);
        // And the router object rides along with its own balance.
        let r = merged.get("router").unwrap();
        assert_eq!(r.get("workers").unwrap().as_u64().unwrap(), 2);
        assert_eq!(r.get("in_flight").unwrap().as_u64().unwrap(), 0);
    }

    #[test]
    fn stats_merge_same_model_on_two_workers_sums_the_rows() {
        let rs = RouterStats::new(2);
        let a = worker_stats(4.0, 0.0, 0.0, vec![("m", 4.0)]);
        let b = worker_stats(6.0, 0.0, 0.0, vec![("m", 6.0)]);
        let merged = merge_stats(&rs, &views(), &[Some(a), Some(b)]);
        let m = merged.get("per_model").unwrap().get("m").unwrap();
        assert_eq!(m.get("requests").unwrap().as_f64().unwrap(), 10.0);
        // The per-entry occupancy recompute: (4+6)/(2+2).
        assert!((m.get("eval_occupancy").unwrap().as_f64().unwrap() - 2.5).abs() < 1e-9);
    }

    #[test]
    fn stats_merge_skips_unreachable_workers() {
        let mut rs = RouterStats::new(2);
        rs.requests = 7;
        rs.forwarded = 5;
        rs.upstream_errors = 2;
        let a = worker_stats(5.0, 0.0, 0.0, vec![]);
        let merged = merge_stats(
            &rs,
            &[views()[0].clone(), WorkerView { addr: "b:2".into(), up: false }],
            &[Some(a), None],
        );
        assert_eq!(merged.get("requests").unwrap().as_f64().unwrap(), 5.0);
        let r = merged.get("router").unwrap();
        assert_eq!(r.get("workers_up").unwrap().as_u64().unwrap(), 1);
        assert_eq!(r.get("upstream_errors").unwrap().as_u64().unwrap(), 2);
        let b = r.get("per_worker").unwrap().get("b:2").unwrap();
        assert!(!b.get("up").unwrap().as_bool().unwrap());
    }

    fn worker_health(draining: bool, panics: u64, models: Vec<(&str, bool)>) -> Json {
        let m: BTreeMap<String, Json> =
            models.into_iter().map(|(n, h)| (n.to_string(), Json::Bool(h))).collect();
        Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("draining", Json::Bool(draining)),
            ("worker_panics", Json::uint(panics)),
            ("models", Json::Obj(m)),
        ])
    }

    #[test]
    fn health_merge_ands_models_and_sums_panics() {
        let a = worker_health(true, 2, vec![("m", true), ("shared", true)]);
        let b = worker_health(false, 3, vec![("shared", false)]);
        let merged = merge_health(&views(), &[Some(a), Some(b)]);
        assert!(!merged.get("draining").unwrap().as_bool().unwrap());
        assert_eq!(merged.get("worker_panics").unwrap().as_u64().unwrap(), 5);
        let models = merged.get("models").unwrap();
        assert!(models.get("m").unwrap().as_bool().unwrap());
        assert!(!models.get("shared").unwrap().as_bool().unwrap());
        let w = merged.get("workers").unwrap().get("a:1").unwrap();
        assert!(w.get("draining").unwrap().as_bool().unwrap());
        assert_eq!(w.get("worker_panics").unwrap().as_u64().unwrap(), 2);
    }

    #[test]
    fn health_merge_draining_needs_every_reachable_worker() {
        let a = worker_health(true, 0, vec![]);
        let b = worker_health(true, 0, vec![]);
        let merged = merge_health(&views(), &[Some(a), Some(b)]);
        assert!(merged.get("draining").unwrap().as_bool().unwrap());
        // One unreachable worker doesn't veto: draining is over REACHABLE.
        let c = worker_health(true, 0, vec![]);
        let merged = merge_health(&views(), &[Some(c), None]);
        assert!(merged.get("draining").unwrap().as_bool().unwrap());
    }

    #[test]
    fn models_merge_unions_sorted() {
        let a = Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("models", Json::Arr(vec![Json::str("b"), Json::str("a")])),
        ]);
        let b = Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("models", Json::Arr(vec![Json::str("c"), Json::str("a")])),
        ]);
        let merged = merge_models(&[Some(a), Some(b)]);
        let names: Vec<String> = match merged.get("models").unwrap() {
            Json::Arr(list) => list.iter().map(|m| m.as_str().unwrap().to_string()).collect(),
            other => panic!("models not an array: {other:?}"),
        };
        assert_eq!(names, vec!["a", "b", "c"]);
    }
}
