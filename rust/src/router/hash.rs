//! Rendezvous (highest-random-weight) hashing over upstream workers.
//!
//! Every router instance, and every test, must agree on which worker owns a
//! model given only the worker address list — no shared state, no
//! coordination. HRW gives that: `score(worker, key)` is a deterministic
//! 64-bit mix of the two identities, the owner is the argmax over workers,
//! and the *rank order* (scores sorted descending) is the failover sequence.
//! Its two properties carry the whole router design:
//!
//! * **Minimal disruption** — adding a worker re-homes only the keys whose
//!   new argmax IS the new worker (≈ 1/N of them); removing a worker
//!   re-homes only the keys it owned, each to its rank-2 worker. No other
//!   key moves, so co-batching concentration survives membership churn.
//! * **Stateless failover** — when a worker's breaker is open the router
//!   just walks the rank order past it; when the breaker closes, traffic
//!   returns to the true owner automatically.
//!
//! The hash is FNV-1a per identity with a splitmix64-style finalizer over
//! the combination — not cryptographic, but well-mixed enough that 2–64
//! workers get an even key split (asserted by the unit tests below).

use crate::coordinator::F32_SUFFIX;

/// FNV-1a 64-bit over raw bytes.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// splitmix64 finalizer: spreads FNV's weak low-bit avalanche.
fn mix(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^= x >> 31;
    x
}

/// The HRW score of one (worker, routing-key) pair. Higher wins.
pub fn score(worker: &str, key: &str) -> u64 {
    mix(fnv1a(worker.as_bytes()) ^ mix(fnv1a(key.as_bytes())))
}

/// The routing key for a model name: the `@f32` precision suffix is
/// stripped so `model@f32` siblings land on the same worker as `model` —
/// they share eval batches worker-side, and splitting them would halve the
/// co-batching opportunity the router exists to concentrate.
pub fn routing_key(model: &str) -> &str {
    model.strip_suffix(F32_SUFFIX).unwrap_or(model)
}

/// Index of the worker that owns `key` (pre-stripped via [`routing_key`]),
/// or `None` for an empty worker list. Ties (astronomically unlikely)
/// break toward the lower index, deterministically.
pub fn pick(workers: &[String], key: &str) -> Option<usize> {
    let (mut best_score, mut best) = (score(workers.first()?, key), 0);
    for (i, w) in workers.iter().enumerate().skip(1) {
        let s = score(w, key);
        if s > best_score {
            (best_score, best) = (s, i);
        }
    }
    Some(best)
}

/// Full failover order for `key`: worker indices sorted by score
/// descending (ties toward the lower index). `rank(..)[0] == pick(..)`.
/// Allocates and sorts all N workers — failover-path only; the submit hot
/// path uses the allocation-free [`pick`] and falls back here when the
/// owner is unavailable.
pub fn rank(workers: &[String], key: &str) -> Vec<usize> {
    let mut scored: Vec<(u64, usize)> =
        workers.iter().enumerate().map(|(i, w)| (score(w, key), i)).collect();
    scored.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
    scored.into_iter().map(|(_, i)| i).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn workers(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("127.0.0.1:{}", 7000 + i)).collect()
    }

    fn keys(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("model_{i}")).collect()
    }

    #[test]
    fn pick_matches_rank_head_and_rank_is_a_permutation() {
        let w = workers(5);
        for key in keys(64) {
            let r = rank(&w, &key);
            let mut sorted = r.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, (0..5).collect::<Vec<_>>());
            assert_eq!(Some(r[0]), pick(&w, &key));
        }
    }

    #[test]
    fn keys_split_roughly_evenly() {
        let w = workers(4);
        let mut counts = [0usize; 4];
        for key in keys(4000) {
            counts[pick(&w, &key).unwrap()] += 1;
        }
        for &c in &counts {
            // Expect 1000 per worker; a 2x band catches any gross bias
            // (a broken mix collapses to one worker entirely).
            assert!((500..2000).contains(&c), "uneven split: {counts:?}");
        }
    }

    #[test]
    fn adding_a_worker_moves_only_the_new_workers_share() {
        let before = workers(2);
        let mut after = before.clone();
        after.push("127.0.0.1:7999".to_string());
        let n = 1000;
        let mut moved = 0;
        for key in keys(n) {
            let old = pick(&before, &key).unwrap();
            let new = pick(&after, &key).unwrap();
            if new != old {
                // The HRW guarantee: every mover moves TO the new worker.
                assert_eq!(new, 2, "key '{key}' moved {old}->{new}, not to the new worker");
                moved += 1;
            }
        }
        // Expected share is 1/3; accept a generous band around it.
        let frac = moved as f64 / n as f64;
        assert!((0.15..0.55).contains(&frac), "moved fraction {frac}");
    }

    #[test]
    fn f32_siblings_share_an_owner() {
        let w = workers(7);
        for key in keys(32) {
            assert_eq!(routing_key(&key), key);
            let sibling = format!("{key}@f32");
            assert_eq!(routing_key(&sibling), key);
            assert_eq!(pick(&w, routing_key(&sibling)), pick(&w, &key));
        }
    }
}
