//! Pooled, pipelined upstream connections.
//!
//! Each upstream worker gets a small fixed pool of non-blocking TCP
//! connections, grown lazily up to `pool_per_worker`. Requests are
//! pipelined FIFO per connection: the wire protocol guarantees exactly one
//! reply per request, in order, so a `VecDeque<Route>` alongside each
//! connection is the complete reply-matching state — no request IDs on the
//! wire. The pool matters because a WORKER admits only one request per
//! connection at a time (its frontend parses the next line only after
//! replying), so per-worker concurrency equals the number of pooled
//! connections, and concentrating a model's traffic on one worker only
//! pays off in co-batching if several of its requests can be in the
//! worker's scheduler at once.
//!
//! Health is a per-upstream [`Breaker`] (the PR-6 shape, threshold 1):
//! any connect failure or connection death opens it for the cooldown, the
//! event loop re-homes the upstream's models by walking the rendezvous
//! rank past it, and the first submit after cooldown probes it again.

use std::collections::VecDeque;
use std::io;
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use crate::coordinator::{Breaker, BreakerConfig};
use crate::server::poll::Interest;

/// Who gets the reply at the head of a connection's FIFO.
#[derive(Clone, Debug)]
pub(crate) enum Route {
    /// A proxied submit: relay the reply line (and any binary payload)
    /// to this client slot, if its generation still matches.
    Client { idx: u32, gen: u32, model: String },
    /// One leg of a stats/health/models fan-out: record the parsed reply
    /// under aggregate `id` at worker slot `widx`.
    Agg { id: u64, widx: usize },
}

/// One pooled non-blocking connection to a worker.
pub(crate) struct UpstreamConn {
    pub stream: TcpStream,
    /// Stale-event guard, same scheme as client slots.
    pub gen: u32,
    /// Inbound bytes from the worker (reply lines + binary payloads).
    pub buf: Vec<u8>,
    /// Prefix of `buf` already scanned for a newline.
    pub scanned: usize,
    /// Binary payload bytes still owed to the head route's reply.
    pub bin_remaining: u64,
    /// Whether that payload is being relayed (false once the head client
    /// vanished mid-payload: the rest is drained and discarded).
    pub bin_to_client: bool,
    /// Outbound request bytes not yet written.
    pub out: Vec<u8>,
    pub written: usize,
    /// Reply owners, oldest first.
    pub fifo: VecDeque<Route>,
    pub interest: Interest,
}

impl UpstreamConn {
    pub fn new(stream: TcpStream, gen: u32) -> UpstreamConn {
        UpstreamConn {
            stream,
            gen,
            buf: Vec::new(),
            scanned: 0,
            bin_remaining: 0,
            bin_to_client: false,
            out: Vec::new(),
            written: 0,
            fifo: VecDeque::new(),
            interest: Interest::READ,
        }
    }
}

/// One upstream worker: its address, health breaker, and connection pool.
pub(crate) struct Upstream {
    /// Resolved connect target.
    pub addr: SocketAddr,
    /// The address string as configured — the rendezvous identity, and the
    /// key used for this worker in stats/health replies.
    pub name: String,
    pub breaker: Breaker,
    pub conns: Vec<Option<UpstreamConn>>,
}

impl Upstream {
    pub fn new(addr: SocketAddr, name: String, cooldown: Duration, pool: usize) -> Upstream {
        Upstream {
            addr,
            name,
            // Threshold 1: a worker process is either there or it isn't —
            // unlike a flaky model eval there is no partial-failure mode
            // worth retrying into, and an open breaker is what bounds how
            // often the (blocking, bounded) connect probe can stall the
            // event loop.
            breaker: Breaker::new(BreakerConfig { threshold: 1, cooldown }),
            conns: (0..pool.max(1)).map(|_| None).collect(),
        }
    }

    /// Bounded blocking connect (the one deliberate stall in the event
    /// loop — see the module doc in `router/mod.rs`), then non-blocking +
    /// nodelay for the pipelined request path.
    pub fn connect(&self, timeout: Duration) -> io::Result<TcpStream> {
        let stream = TcpStream::connect_timeout(&self.addr, timeout)?;
        stream.set_nonblocking(true)?;
        stream.set_nodelay(true)?;
        Ok(stream)
    }

    /// Any live pooled connection?
    pub fn up(&self) -> bool {
        self.conns.iter().any(Option::is_some)
    }

    /// Live connection with nothing in flight, if any — preferred over
    /// pipelining onto a busy one, since the worker serializes per conn.
    pub fn idle_conn(&self) -> Option<usize> {
        self.conns
            .iter()
            .position(|c| c.as_ref().is_some_and(|uc| uc.fifo.is_empty()))
    }

    /// Unused pool slot, if the pool hasn't grown to its cap yet.
    pub fn free_slot(&self) -> Option<usize> {
        self.conns.iter().position(Option::is_none)
    }

    /// Live connection with the shortest FIFO (fallback when every
    /// connection is busy and the pool is full).
    pub fn least_loaded(&self) -> Option<usize> {
        self.conns
            .iter()
            .enumerate()
            .filter_map(|(i, c)| c.as_ref().map(|uc| (uc.fifo.len(), i)))
            .min()
            .map(|(_, i)| i)
    }
}
