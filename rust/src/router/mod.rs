//! Multi-process sharded serving: the router tier.
//!
//! One process can only scale co-batching as far as its cores; this module
//! multiplies that by proxying the existing line protocol across N
//! independent worker processes (`deis serve`), with the property that
//! makes sharding *worth it* for a batching sampler: all traffic for a
//! model — including its `@f32` precision sibling — deterministically
//! lands on ONE worker, so the per-model co-batching opportunity
//! concentrates instead of fragmenting. See the "Router tier" section of
//! the wire doc in [`crate::server`] for the client-visible contract; this
//! doc covers the machinery.
//!
//! ## Structure
//!
//! A single event-loop thread (the same epoll [`Poller`] the server
//! frontend runs on) owns everything: the listener, every client
//! connection, and every upstream connection. Single-threaded on purpose —
//! the router does no math; it parses one key per line ([`route_scan`],
//! zero-copy via [`Scanner`]) and shovels bytes, so one core saturates
//! well past what N workers can solve, and single ownership means every
//! counter is a plain `u64` and every FIFO is a plain `VecDeque`.
//!
//! * **Routing** — rendezvous (HRW) hashing over the configured upstream
//!   address strings ([`hash`]): owner = argmax score (the allocation-free
//!   [`hash::pick`], the per-submit hot path); only when the owner is
//!   unavailable is the full [`hash::rank`] failover order built and
//!   walked past upstreams with open breakers. Stateless, so every
//!   router (and every test) independently agrees on placement.
//! * **Upstream pooling** ([`pool`]) — per worker, a lazily-grown pool of
//!   at most `pool_per_worker` pipelined connections; the per-connection
//!   reply FIFO is the complete matching state (one reply per line, in
//!   order). A worker admits one request per connection at a time, so the
//!   pool size IS the per-worker concurrency.
//! * **Binary passthrough** — a relayed reply line is scanned for
//!   `bin_bytes` ([`crate::server::wire::reply_bin_bytes`], O(first key)
//!   on bin headers); the payload is then forwarded as raw bytes, never
//!   decoded. Proxied replies are byte-identical to direct ones.
//! * **Fan-in** ([`stats`]) — stats/health/models commands broadcast to
//!   every reachable worker; replies aggregate under an [`Agg`] ticket and
//!   merge into one reply in the worker wire schema plus a `"router"`
//!   object. The client's pending flag holds its reply order meanwhile.
//!
//! ## Failure semantics
//!
//! Any connect failure, connection death, or protocol corruption on an
//! upstream fails the WHOLE upstream: its breaker (the per-model
//! `Breaker`
//! shape, threshold 1) opens for `cooldown`, every pooled connection is
//! torn down, and every in-flight FIFO entry is answered immediately with
//! an `"upstream unavailable"` error — counted in `upstream_errors` and
//! attributed per model, so the router's own balance
//! (`requests == forwarded + upstream_errors + in_flight`) always holds.
//! Replies already buffered from the dying worker are relayed first: a
//! reply the worker managed to send is never lost. The one un-answerable
//! case — the worker died mid-binary-payload, after header bytes reached
//! the client — tears the client connection down, because an error line
//! injected into a half-delivered payload would be corruption, not help.
//! Subsequent submits for the dead worker's models re-home to the next
//! worker in rendezvous rank order; after `cooldown` the next submit
//! probes the original owner and traffic snaps back on success.
//!
//! ## Deliberate trade-offs
//!
//! * The lazy upstream connect is a *blocking* `connect_timeout` on the
//!   loop thread (bounded by `connect_timeout`, default 250ms). The
//!   threshold-1 breaker caps the stall rate at one probe per cooldown
//!   per dead worker; localhost/rack connects to a live worker are tens
//!   of microseconds. Fan-out commands additionally probe at most ONE
//!   connection-less worker each (the rest are skipped with a `None`
//!   reply slot), so K simultaneously dead-but-cooled-down workers cost
//!   one stats command at most one probe stall, never K.
//! * The router imposes no per-request timeout of its own: end-to-end
//!   latency budgets belong to the request's `deadline_ms` (the worker
//!   enforces it); a hung worker process is surfaced on connection death
//!   or by the client's own read timeout, exactly as with a direct
//!   connection.
//! * Merged `p50_us`/`p99_us` take the per-worker MAX (the wire carries
//!   quantiles, not histograms); `mean_us` is request-weighted and exact.

pub mod hash;
pub(crate) mod pool;
pub mod stats;

use std::collections::{HashMap, HashSet};
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::os::fd::AsRawFd;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::server::poll::{Event, Interest, Poller};
use crate::server::wire;
use crate::util::json::{Json, Scanner};

use pool::{Route, Upstream, UpstreamConn};
use stats::{RouterStats, WorkerView};

/// Router hardening knobs. The client-facing ones mirror
/// [`crate::server::ServeOptions`]; the upstream ones are router-specific.
#[derive(Clone, Copy, Debug)]
pub struct RouterOptions {
    /// Concurrent CLIENT connections; excess get one "router at connection
    /// capacity" error line and are closed.
    pub max_conns: usize,
    /// Mid-line client read stall bound (slowloris guard, swept).
    pub read_timeout: Duration,
    /// Client write-progress stall bound (swept).
    pub write_timeout: Duration,
    /// Client request-line byte cap.
    pub max_line_bytes: usize,
    /// Pooled connections per worker — also the per-worker concurrency
    /// cap, since a worker serializes requests per connection.
    pub pool_per_worker: usize,
    /// Bound on the blocking lazy upstream connect (see module doc).
    pub connect_timeout: Duration,
    /// Upstream breaker cooldown after a failure.
    pub cooldown: Duration,
}

impl Default for RouterOptions {
    fn default() -> RouterOptions {
        RouterOptions {
            max_conns: 1024,
            read_timeout: Duration::from_secs(30),
            write_timeout: Duration::from_secs(30),
            max_line_bytes: 256 * 1024,
            pool_per_worker: 8,
            connect_timeout: Duration::from_millis(250),
            cooldown: Duration::from_secs(1),
        }
    }
}

/// Route the router's listener reports on.
const LISTENER_TOKEN: u64 = u64::MAX;
/// Token bit distinguishing upstream connections from clients.
const UPSTREAM_BIT: u64 = 1 << 63;
/// Generations are 31 bits so a client token never sets [`UPSTREAM_BIT`].
const GEN_MASK: u32 = 0x7FFF_FFFF;
/// Same per-connection outbound backpressure bound as the server.
const OUT_HIGH_WATER: usize = 256 * 1024;

fn client_token(idx: u32, gen: u32) -> u64 {
    ((gen as u64) << 32) | idx as u64
}

fn upstream_token(widx: usize, pidx: usize, gen: u32) -> u64 {
    // `serve_with` caps the worker count and the pool clamp caps slots, so
    // widx/pidx are at most 0xFFFE and the all-ones pattern
    // ([`LISTENER_TOKEN`]) is unreachable from this encoding.
    debug_assert!(widx < 0xFFFF && pidx < 0xFFFF, "packed token would collide with the listener");
    UPSTREAM_BIT | ((gen as u64) << 32) | ((widx as u64) << 16) | pidx as u64
}

/// Per-client-connection state machine. Same shape as the server's `Conn`
/// except `pending` is a bare flag: the reply is produced by an upstream
/// (or a fan-in merge), not by a local coordinator completion.
struct ClientConn {
    stream: TcpStream,
    gen: u32,
    buf: Vec<u8>,
    scanned: usize,
    out: Vec<u8>,
    written: usize,
    /// A request is in flight (proxied or aggregating). While set, no
    /// further lines are parsed and the socket is not read: one request
    /// per connection at a time, replies strictly in order — exactly the
    /// worker frontend's contract, so a client cannot tell the tiers
    /// apart.
    pending: bool,
    eof: bool,
    close_after_write: bool,
    interest: Interest,
    last_read_progress: Instant,
    last_write_progress: Instant,
}

/// See `note_outbound` in the server frontend: stamp the write clock when
/// `out` goes from drained to non-empty.
fn note_outbound(conn: &mut ClientConn) {
    if conn.out.len() == conn.written {
        conn.last_write_progress = Instant::now();
    }
}

/// Drain as much of `out` as the socket accepts. True = dead.
fn write_client(conn: &mut ClientConn) -> bool {
    while conn.written < conn.out.len() {
        match (&conn.stream).write(&conn.out[conn.written..]) {
            Ok(0) => return true,
            Ok(n) => {
                conn.written += n;
                conn.last_write_progress = Instant::now();
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => return true,
        }
    }
    if conn.written > 0 && conn.written == conn.out.len() {
        conn.out.clear();
        conn.written = 0;
    }
    false
}

/// Budgeted read (level-triggered epoll re-reports the rest). True = dead.
fn read_client(conn: &mut ClientConn) -> bool {
    let mut tmp = [0u8; 16 * 1024];
    let mut budget: usize = 16;
    loop {
        match (&conn.stream).read(&mut tmp) {
            Ok(0) => {
                conn.eof = true;
                return false;
            }
            Ok(n) => {
                conn.buf.extend_from_slice(&tmp[..n]);
                conn.last_read_progress = Instant::now();
                if n < tmp.len() {
                    return false;
                }
                budget -= 1;
                if budget == 0 {
                    return false;
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return false,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => return true,
        }
    }
}

fn write_upstream(uc: &mut UpstreamConn) -> bool {
    while uc.written < uc.out.len() {
        match (&uc.stream).write(&uc.out[uc.written..]) {
            Ok(0) => return true,
            Ok(n) => uc.written += n,
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => return true,
        }
    }
    if uc.written > 0 && uc.written == uc.out.len() {
        uc.out.clear();
        uc.written = 0;
    }
    false
}

/// Returns (dead, eof). EOF is not "dead" yet: buffered complete replies
/// are relayed before the upstream is failed, so nothing a worker managed
/// to send is ever lost.
fn read_upstream(uc: &mut UpstreamConn) -> (bool, bool) {
    let mut tmp = [0u8; 16 * 1024];
    let mut budget: usize = 16;
    loop {
        match (&uc.stream).read(&mut tmp) {
            Ok(0) => return (false, true),
            Ok(n) => {
                uc.buf.extend_from_slice(&tmp[..n]);
                if n < tmp.len() {
                    return (false, false);
                }
                budget -= 1;
                if budget == 0 {
                    return (false, false);
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return (false, false),
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => return (true, false),
        }
    }
}

/// Over-long client line: one error, doom the connection (the line's tail
/// is unread; resync is impossible). Same contract as the worker.
fn too_long(conn: &mut ClientConn, opts: &RouterOptions) {
    note_outbound(conn);
    wire::error_reply(
        &mut conn.out,
        &format!("request line too long (max {} bytes)", opts.max_line_bytes),
    );
    conn.buf.clear();
    conn.scanned = 0;
    conn.close_after_write = true;
}

/// Shed a connection refused at the accept gate: one error line, close.
fn shed(mut stream: TcpStream, opts: &RouterOptions) {
    let _ = stream.set_write_timeout(Some(opts.write_timeout));
    let mut out = Vec::new();
    wire::error_reply(
        &mut out,
        &format!("router at connection capacity ({}); retry later", opts.max_conns),
    );
    let _ = stream.write_all(&out);
}

/// What the zero-copy routing scan learned about one client line.
#[derive(Debug, PartialEq)]
enum Scan {
    /// A submit line; the value is its routing model ("" when absent —
    /// the worker owns the resulting "missing model" error text).
    Submit(String),
    /// Anything the scanner cannot settle — a `cmd` key, string escapes,
    /// malformed JSON — falls back to the owned tree parse.
    Tree,
}

/// Extract just the `model` key from a submit line, zero-copy. Mirrors the
/// scan-loop shape of [`wire::parse_submit_fast`], including last-wins
/// duplicate keys, but looks at nothing else: the router routes, the
/// worker validates.
fn route_scan(line: &str) -> Scan {
    let mut sc = Scanner::new(line);
    if sc.begin_object().is_err() {
        return Scan::Tree;
    }
    let mut model: Option<&str> = None;
    loop {
        match sc.next_key() {
            Ok(Some("cmd")) => return Scan::Tree,
            Ok(Some("model")) => match sc.value_str() {
                Ok(s) => model = Some(s),
                Err(_) => return Scan::Tree,
            },
            Ok(Some(_)) => {
                if sc.skip_value().is_err() {
                    return Scan::Tree;
                }
            }
            Ok(None) => break,
            Err(_) => return Scan::Tree,
        }
    }
    if sc.end().is_err() {
        return Scan::Tree;
    }
    Scan::Submit(model.unwrap_or("").to_string())
}

/// Reproduce the worker's cmd-name extraction exactly (same calls, same
/// error texts) so a bad cmd line gets an identical reply via either tier.
fn cmd_name(v: &Json) -> Result<&str> {
    v.get("cmd")?.as_str()
}

#[derive(Clone, Copy, Debug)]
enum CmdKind {
    Stats,
    Health,
    Models,
}

/// One in-progress stats/health/models fan-out.
struct Agg {
    client: (u32, u32),
    kind: CmdKind,
    /// Per-worker reply slot; `None` = unreachable (or failed mid-cmd).
    results: Vec<Option<Json>>,
    outstanding: usize,
}

/// Parse the `deis serving on ADDR (models: ...)` banner a worker prints
/// once its listener is bound — how `--spawn-workers` learns each child's
/// ephemeral port.
pub fn parse_serve_banner(line: &str) -> Option<SocketAddr> {
    let rest = line.trim().strip_prefix("deis serving on ")?;
    rest.split_whitespace().next()?.parse().ok()
}

struct Router {
    poller: Poller,
    listener: TcpListener,
    conns: Vec<Option<ClientConn>>,
    free: Vec<u32>,
    next_gen: u32,
    next_ugen: u32,
    conn_count: usize,
    /// Upstream identities in slot order — the rendezvous universe.
    names: Vec<String>,
    upstreams: Vec<Upstream>,
    aggs: HashMap<u64, Agg>,
    next_agg: u64,
    stats: RouterStats,
    opts: RouterOptions,
}

/// Start a router over the given upstream workers with default options.
/// Returns the bound address (port 0 allowed). Workers need not be up yet
/// — connections are opened lazily per routed request.
pub fn serve(upstreams: Vec<String>, addr: &str) -> Result<SocketAddr> {
    serve_with(upstreams, addr, RouterOptions::default())
}

/// [`serve`] with explicit options.
pub fn serve_with(
    upstreams: Vec<String>,
    addr: &str,
    opts: RouterOptions,
) -> Result<SocketAddr> {
    if upstreams.is_empty() {
        bail!("router needs at least one upstream worker");
    }
    if upstreams.len() > 0xFFFF {
        bail!("router supports at most 65535 upstream workers");
    }
    // Duplicate address strings would get identical rendezvous scores (all
    // traffic tie-breaking to the lower slot) while fan-out commands hit
    // both slots of the same worker and double-sum its counters.
    let mut seen: HashSet<&str> = HashSet::with_capacity(upstreams.len());
    for name in &upstreams {
        if !seen.insert(name.as_str()) {
            bail!("duplicate upstream '{name}': each worker address may be listed once");
        }
    }
    let pool = opts.pool_per_worker.clamp(1, 0xFFFF);
    let mut ups = Vec::with_capacity(upstreams.len());
    for name in &upstreams {
        let resolved = name
            .to_socket_addrs()
            .with_context(|| format!("resolving upstream '{name}'"))?
            .next()
            .ok_or_else(|| anyhow!("upstream '{name}' resolved to no address"))?;
        ups.push(Upstream::new(resolved, name.clone(), opts.cooldown, pool));
    }
    let listener =
        TcpListener::bind(addr).with_context(|| format!("binding router to {addr}"))?;
    let local = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let poller = Poller::new()?;
    poller.register(listener.as_raw_fd(), LISTENER_TOKEN, Interest::READ)?;
    let stats = RouterStats::new(upstreams.len());
    let router = Router {
        poller,
        listener,
        conns: Vec::new(),
        free: Vec::new(),
        next_gen: 0,
        next_ugen: 0,
        conn_count: 0,
        names: upstreams,
        upstreams: ups,
        aggs: HashMap::new(),
        next_agg: 0,
        stats,
        opts,
    };
    std::thread::Builder::new()
        .name("deis-router".to_string())
        .spawn(move || router.run())?;
    Ok(local)
}

impl Router {
    fn run(mut self) {
        let tick = (self.opts.read_timeout.min(self.opts.write_timeout) / 4)
            .clamp(Duration::from_millis(10), Duration::from_secs(1));
        let mut events: Vec<Event> = Vec::new();
        let mut last_sweep = Instant::now();
        loop {
            events.clear();
            if self.poller.wait(&mut events, Some(tick)).is_err() {
                return;
            }
            let ready: Vec<(u64, bool)> =
                events.iter().map(|ev| (ev.token, ev.hangup)).collect();
            for (token, hangup) in ready {
                if token == LISTENER_TOKEN {
                    self.accept_burst();
                } else if token & UPSTREAM_BIT != 0 {
                    let gen = ((token >> 32) & GEN_MASK as u64) as u32;
                    let widx = ((token >> 16) & 0xFFFF) as usize;
                    let pidx = (token & 0xFFFF) as usize;
                    self.drive_upstream(widx, pidx, Some(gen), hangup);
                } else {
                    let idx = (token & 0xFFFF_FFFF) as u32;
                    let gen = ((token >> 32) & GEN_MASK as u64) as u32;
                    self.drive_client(idx, Some(gen), true, hangup);
                }
            }
            if last_sweep.elapsed() >= tick {
                self.sweep();
                last_sweep = Instant::now();
            }
        }
    }

    fn accept_burst(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _addr)) => self.admit(stream),
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => return,
            }
        }
    }

    fn admit(&mut self, stream: TcpStream) {
        if self.conn_count >= self.opts.max_conns.max(1) {
            shed(stream, &self.opts);
            return;
        }
        if stream.set_nonblocking(true).is_err() {
            return;
        }
        let idx = match self.free.pop() {
            Some(i) => i,
            None => {
                self.conns.push(None);
                (self.conns.len() - 1) as u32
            }
        };
        self.next_gen = self.next_gen.wrapping_add(1) & GEN_MASK;
        let gen = self.next_gen;
        if self.poller.register(stream.as_raw_fd(), client_token(idx, gen), Interest::READ).is_err()
        {
            self.free.push(idx);
            return;
        }
        let now = Instant::now();
        self.conns[idx as usize] = Some(ClientConn {
            stream,
            gen,
            buf: Vec::new(),
            scanned: 0,
            out: Vec::new(),
            written: 0,
            pending: false,
            eof: false,
            close_after_write: false,
            interest: Interest::READ,
            last_read_progress: now,
            last_write_progress: now,
        });
        self.conn_count += 1;
    }

    fn client_mut(&mut self, idx: u32, gen: u32) -> Option<&mut ClientConn> {
        self.conns.get_mut(idx as usize)?.as_mut().filter(|c| c.gen == gen)
    }

    fn drop_client(&mut self, idx: u32, conn: ClientConn) {
        let _ = self.poller.deregister(conn.stream.as_raw_fd());
        self.free.push(idx);
        self.conn_count -= 1;
    }

    fn teardown_client(&mut self, idx: u32, gen: u32) {
        let Some(slot) = self.conns.get_mut(idx as usize) else { return };
        if slot.as_ref().is_some_and(|c| c.gen == gen) {
            let conn = slot.take().expect("slot checked non-empty");
            self.drop_client(idx, conn);
        }
    }

    /// Advance one client's state machine (the server frontend's `drive`,
    /// with upstream dispatch instead of coordinator submit). Upstream
    /// connections touched by dispatched lines are flushed AFTER the
    /// client slot is settled, because a flush failure fails a whole
    /// worker and may need to write errors back into this very slot.
    fn drive_client(&mut self, idx: u32, gen: Option<u32>, do_read: bool, hangup: bool) {
        let Some(slot) = self.conns.get_mut(idx as usize) else { return };
        let Some(mut conn) = slot.take() else { return };
        if let Some(g) = gen {
            if conn.gen != g {
                self.conns[idx as usize] = Some(conn); // stale event
                return;
            }
        }
        if hangup && conn.pending {
            // Peer gone mid-request: HUP is reported regardless of
            // interest, so keeping the slot would spin the loop until the
            // upstream replies. The in-flight FIFO entry later misses the
            // recycled generation and is dropped (forwarded still counts).
            self.drop_client(idx, conn);
            return;
        }
        let mut touched: Vec<(usize, usize)> = Vec::new();
        let mut dead = write_client(&mut conn);
        if !dead && do_read && !conn.pending && !conn.eof && !conn.close_after_write {
            dead |= read_client(&mut conn);
        }
        if !dead {
            self.process_client_buffer(&mut conn, idx, &mut touched);
            dead |= write_client(&mut conn);
        }
        let backlog = conn.out.len() - conn.written;
        let finished = backlog == 0
            && (conn.close_after_write || (conn.eof && !conn.pending && conn.buf.is_empty()));
        if dead || finished {
            self.drop_client(idx, conn);
        } else {
            let want = Interest {
                read: !conn.pending
                    && !conn.close_after_write
                    && !conn.eof
                    && backlog < OUT_HIGH_WATER,
                write: backlog > 0,
            };
            let mut ok = true;
            if want != conn.interest {
                if self
                    .poller
                    .modify(conn.stream.as_raw_fd(), client_token(idx, conn.gen), want)
                    .is_ok()
                {
                    conn.interest = want;
                } else {
                    ok = false;
                }
            }
            if ok {
                self.conns[idx as usize] = Some(conn);
            } else {
                self.drop_client(idx, conn);
            }
        }
        let mut drives: Vec<(u32, u32)> = Vec::new();
        for &(w, p) in &touched {
            self.flush_upstream(w, p, &mut drives);
        }
        for (i, g) in drives {
            self.drive_client(i, Some(g), false, false);
        }
    }

    /// Consume complete client lines; same invariants as the server's
    /// `process_buffer`.
    fn process_client_buffer(
        &mut self,
        conn: &mut ClientConn,
        idx: u32,
        touched: &mut Vec<(usize, usize)>,
    ) {
        loop {
            if conn.pending || conn.close_after_write {
                return;
            }
            if conn.out.len() - conn.written >= OUT_HIGH_WATER {
                return;
            }
            match conn.buf[conn.scanned..].iter().position(|&b| b == b'\n') {
                Some(rel) => {
                    let pos = conn.scanned + rel;
                    if pos > self.opts.max_line_bytes {
                        too_long(conn, &self.opts);
                        return;
                    }
                    let buf_taken = std::mem::take(&mut conn.buf);
                    self.dispatch_client(conn, idx, &buf_taken[..pos], touched);
                    conn.buf = buf_taken;
                    conn.buf.drain(..=pos);
                    conn.scanned = 0;
                }
                None => {
                    conn.scanned = conn.buf.len();
                    if conn.buf.len() > self.opts.max_line_bytes {
                        too_long(conn, &self.opts);
                    } else if conn.eof && !conn.buf.is_empty() {
                        let taken = std::mem::take(&mut conn.buf);
                        conn.scanned = 0;
                        self.dispatch_client(conn, idx, &taken, touched);
                    }
                    return;
                }
            }
        }
    }

    /// Classify and route one client line.
    fn dispatch_client(
        &mut self,
        conn: &mut ClientConn,
        idx: u32,
        bytes: &[u8],
        touched: &mut Vec<(usize, usize)>,
    ) {
        let owned;
        let line = match std::str::from_utf8(bytes) {
            Ok(s) => s,
            Err(_) => {
                owned = String::from_utf8_lossy(bytes).into_owned();
                owned.as_str()
            }
        };
        if line.trim().is_empty() {
            // Workers send no reply for blank lines; forwarding one would
            // desynchronize the per-connection reply FIFO. Skip locally.
            return;
        }
        if let Scan::Submit(model) = route_scan(line) {
            self.submit_route(conn, idx, line, &model, touched);
            return;
        }
        match Json::parse(line) {
            Ok(v) => {
                if v.opt("cmd").is_some() {
                    self.cmd_route(conn, idx, &v, touched);
                } else {
                    // Valid JSON the scanner couldn't settle (escapes in
                    // the model name, say): still a submit; the tree
                    // supplies the routing key and the worker validates.
                    let model =
                        v.opt("model").and_then(|m| m.as_str().ok()).unwrap_or("").to_string();
                    self.submit_route(conn, idx, line, &model, touched);
                }
            }
            Err(e) => {
                // Same tree parser, same `{e:#}` formatting => the error
                // text is byte-identical to the worker's.
                self.stats.bad_lines += 1;
                note_outbound(conn);
                wire::error_reply(&mut conn.out, &format!("{e:#}"));
            }
        }
    }

    /// Route one submit line to a healthy worker, forwarding the line
    /// verbatim. Hot path: one allocation-free [`hash::pick`] argmax; the
    /// full (allocating, sorting) [`hash::rank`] failover order is built
    /// only when the owner is unavailable — an open breaker or a failed
    /// connect — which the steady state never hits.
    fn submit_route(
        &mut self,
        conn: &mut ClientConn,
        idx: u32,
        line: &str,
        model: &str,
        touched: &mut Vec<(usize, usize)>,
    ) {
        self.stats.requests += 1;
        let key = hash::routing_key(model);
        if let Some(owner) = hash::pick(&self.names, key) {
            if self.try_submit(owner, conn, idx, line, model, touched) {
                return;
            }
            // rank()[0] == pick(), so skipping the owner walks the rank
            // order exactly as before, minus the already-failed head.
            for widx in hash::rank(&self.names, key) {
                if widx != owner && self.try_submit(widx, conn, idx, line, model, touched) {
                    return;
                }
            }
        }
        // Nothing reachable: answer locally, on the router's own balance.
        self.stats.upstream_errors += 1;
        *self.stats.per_model_errors.entry(model.to_string()).or_insert(0) += 1;
        note_outbound(conn);
        wire::error_reply(
            &mut conn.out,
            &format!("upstream unavailable: no healthy worker (model '{model}')"),
        );
    }

    /// Try to enqueue one submit toward `widx`. True = enqueued (the
    /// client is now pending); false = this worker is unavailable.
    fn try_submit(
        &mut self,
        widx: usize,
        conn: &mut ClientConn,
        idx: u32,
        line: &str,
        model: &str,
        touched: &mut Vec<(usize, usize)>,
    ) -> bool {
        if self.upstreams[widx].breaker.is_open() {
            return false;
        }
        let Some(pidx) = self.ensure_upstream_conn(widx) else { return false };
        let Some(uc) = self.upstreams[widx].conns[pidx].as_mut() else { return false };
        uc.out.extend_from_slice(line.as_bytes());
        uc.out.push(b'\n');
        uc.fifo.push_back(Route::Client { idx, gen: conn.gen, model: model.to_string() });
        self.stats.per_worker[widx].routed += 1;
        conn.pending = true;
        touched.push((widx, pidx));
        true
    }

    /// Fan a stats/health/models command out to every reachable worker.
    fn cmd_route(
        &mut self,
        conn: &mut ClientConn,
        idx: u32,
        v: &Json,
        touched: &mut Vec<(usize, usize)>,
    ) {
        let cmd = match cmd_name(v) {
            Ok(c) => c,
            Err(e) => {
                note_outbound(conn);
                wire::error_reply(&mut conn.out, &format!("{e:#}"));
                return;
            }
        };
        let kind = match cmd {
            "stats" => CmdKind::Stats,
            "health" => CmdKind::Health,
            "models" => CmdKind::Models,
            other => {
                // Answered locally, with the worker's exact text.
                note_outbound(conn);
                wire::error_reply(&mut conn.out, &format!("unknown cmd '{other}'"));
                return;
            }
        };
        self.stats.cmds += 1;
        let line = format!("{{\"cmd\":\"{cmd}\"}}\n");
        let id = self.next_agg;
        self.next_agg += 1;
        let results: Vec<Option<Json>> = (0..self.upstreams.len()).map(|_| None).collect();
        let mut outstanding = 0;
        // At most ONE connection-less worker gets the blocking connect
        // probe per fan-out command: with K workers dead-but-cooled-down,
        // probing them all would stall the loop up to K * connect_timeout
        // on a single stats command. Skipped workers keep their `None`
        // reply slot (already legal); successive commands — or any submit
        // routed their way — probe the rest.
        let mut probed = false;
        for widx in 0..self.upstreams.len() {
            if self.upstreams[widx].breaker.is_open() {
                continue;
            }
            let pidx = if self.upstreams[widx].up() {
                // A live pool: pipeline onto it; a fan-out leg never needs
                // to grow the pool (no blocking connect at all here).
                self.upstreams[widx]
                    .idle_conn()
                    .or_else(|| self.upstreams[widx].least_loaded())
            } else if !probed {
                probed = true;
                self.ensure_upstream_conn(widx)
            } else {
                None
            };
            let Some(pidx) = pidx else { continue };
            let Some(uc) = self.upstreams[widx].conns[pidx].as_mut() else { continue };
            uc.out.extend_from_slice(line.as_bytes());
            uc.fifo.push_back(Route::Agg { id, widx });
            outstanding += 1;
            touched.push((widx, pidx));
        }
        if outstanding == 0 {
            // Every worker down: merge all-None immediately (stats still
            // answer — that is exactly when an operator needs them).
            let reply = self.finalize_kind(kind, &results, None);
            note_outbound(conn);
            conn.out.extend_from_slice(reply.to_string().as_bytes());
            conn.out.push(b'\n');
        } else {
            self.aggs.insert(id, Agg { client: (idx, conn.gen), kind, results, outstanding });
            conn.pending = true;
        }
    }

    /// A live connection to `widx`, growing the pool or probing a lazy
    /// connect as needed. `None` = the worker is unreachable right now
    /// (its breaker has been notified).
    fn ensure_upstream_conn(&mut self, widx: usize) -> Option<usize> {
        if let Some(p) = self.upstreams[widx].idle_conn() {
            return Some(p);
        }
        let timeout = self.opts.connect_timeout;
        if let Some(p) = self.upstreams[widx].free_slot() {
            match self.upstreams[widx].connect(timeout) {
                Ok(stream) => {
                    self.next_ugen = self.next_ugen.wrapping_add(1) & GEN_MASK;
                    let gen = self.next_ugen;
                    let token = upstream_token(widx, p, gen);
                    if self.poller.register(stream.as_raw_fd(), token, Interest::READ).is_ok() {
                        self.upstreams[widx].breaker.on_success();
                        self.upstreams[widx].conns[p] = Some(UpstreamConn::new(stream, gen));
                        return Some(p);
                    }
                }
                Err(_) => {
                    // A refused grow-connect opens the breaker even while
                    // sibling connections still work — the worker is
                    // degraded either way, and the cooldown re-probe
                    // restores it.
                    self.upstreams[widx].breaker.on_failure();
                    return self.upstreams[widx].least_loaded();
                }
            }
        }
        self.upstreams[widx].least_loaded()
    }

    /// Write an upstream's queued request bytes and settle its interest.
    fn flush_upstream(&mut self, widx: usize, pidx: usize, drives: &mut Vec<(u32, u32)>) {
        let (dead, fd, gen, want, cur) = {
            let Some(uc) = self.upstreams[widx].conns[pidx].as_mut() else { return };
            let dead = write_upstream(uc);
            let backlog = uc.out.len() - uc.written;
            (
                dead,
                uc.stream.as_raw_fd(),
                uc.gen,
                Interest { read: true, write: backlog > 0 },
                uc.interest,
            )
        };
        if dead {
            self.fail_worker(widx, drives);
            return;
        }
        if want != cur {
            if self.poller.modify(fd, upstream_token(widx, pidx, gen), want).is_ok() {
                if let Some(uc) = self.upstreams[widx].conns[pidx].as_mut() {
                    uc.interest = want;
                }
            } else {
                self.fail_worker(widx, drives);
            }
        }
    }

    /// Advance one upstream connection: write queued requests, read reply
    /// bytes, relay complete replies, then settle or fail.
    fn drive_upstream(&mut self, widx: usize, pidx: usize, gen: Option<u32>, _hangup: bool) {
        let Some(mut uc) = self
            .upstreams
            .get_mut(widx)
            .and_then(|w| w.conns.get_mut(pidx))
            .and_then(Option::take)
        else {
            return;
        };
        if let Some(g) = gen {
            if uc.gen != g {
                self.upstreams[widx].conns[pidx] = Some(uc); // stale event
                return;
            }
        }
        let mut drives: Vec<(u32, u32)> = Vec::new();
        let mut dead = write_upstream(&mut uc);
        let (d2, eof) = read_upstream(&mut uc);
        dead |= d2;
        // Relay even when dying: replies the worker delivered before the
        // failure still reach their clients.
        let corrupt = self.relay_upstream(widx, &mut uc, &mut drives);
        let mut failed = dead || eof || corrupt;
        if !failed {
            let backlog = uc.out.len() - uc.written;
            let want = Interest { read: true, write: backlog > 0 };
            if want != uc.interest {
                let token = upstream_token(widx, pidx, uc.gen);
                if self.poller.modify(uc.stream.as_raw_fd(), token, want).is_ok() {
                    uc.interest = want;
                } else {
                    failed = true;
                }
            }
        }
        if failed {
            let _ = self.poller.deregister(uc.stream.as_raw_fd());
            self.fail_conn_routes(widx, uc, &mut drives);
            self.fail_worker(widx, &mut drives);
        } else {
            self.upstreams[widx].conns[pidx] = Some(uc);
        }
        for (i, g) in drives {
            self.drive_client(i, Some(g), false, false);
        }
    }

    /// Consume the upstream's inbound buffer: reply lines relayed
    /// verbatim, binary payloads streamed through by byte count. Returns
    /// true on protocol corruption (unsolicited bytes, unparseable reply,
    /// absurd payload size) — the caller fails the worker.
    fn relay_upstream(
        &mut self,
        widx: usize,
        uc: &mut UpstreamConn,
        drives: &mut Vec<(u32, u32)>,
    ) -> bool {
        loop {
            if uc.bin_remaining > 0 {
                if uc.buf.is_empty() {
                    return false;
                }
                let k = uc.buf.len().min(uc.bin_remaining as usize);
                if uc.bin_to_client {
                    let target = match uc.fifo.front() {
                        Some(Route::Client { idx, gen, .. }) => Some((*idx, *gen)),
                        _ => None,
                    };
                    match target.and_then(|(i, g)| self.client_mut(i, g).map(|c| (i, g, c))) {
                        Some((i, g, conn)) => {
                            note_outbound(conn);
                            conn.out.extend_from_slice(&uc.buf[..k]);
                            drives.push((i, g));
                        }
                        // Client vanished mid-payload: drain and discard
                        // the rest so the FIFO stays aligned.
                        None => uc.bin_to_client = false,
                    }
                }
                uc.buf.drain(..k);
                uc.scanned = 0;
                uc.bin_remaining -= k as u64;
                if uc.bin_remaining == 0 {
                    self.complete_head(widx, uc, None, drives);
                }
                continue;
            }
            let Some(rel) = uc.buf[uc.scanned..].iter().position(|&b| b == b'\n') else {
                uc.scanned = uc.buf.len();
                return false;
            };
            let pos = uc.scanned + rel;
            let buf_taken = std::mem::take(&mut uc.buf);
            let corrupt = self.relay_line(widx, uc, &buf_taken[..pos], drives);
            uc.buf = buf_taken;
            uc.buf.drain(..=pos);
            uc.scanned = 0;
            if corrupt {
                return true;
            }
        }
    }

    /// Relay one complete upstream reply line to its FIFO-head owner.
    fn relay_line(
        &mut self,
        widx: usize,
        uc: &mut UpstreamConn,
        bytes: &[u8],
        drives: &mut Vec<(u32, u32)>,
    ) -> bool {
        enum Head {
            Client(u32, u32),
            Agg,
        }
        let Ok(line) = std::str::from_utf8(bytes) else { return true };
        let head = match uc.fifo.front() {
            // A reply with nothing in flight (e.g. a worker-side shed
            // line) means the FIFO and the wire disagree: corruption.
            None => return true,
            Some(Route::Client { idx, gen, .. }) => Head::Client(*idx, *gen),
            Some(Route::Agg { .. }) => Head::Agg,
        };
        match head {
            Head::Client(cidx, cgen) => {
                let bin = match wire::reply_bin_bytes(line) {
                    Ok(n) => n.unwrap_or(0),
                    Err(_) => return true,
                };
                if bin > wire::MAX_BIN_REPLY_BYTES {
                    return true;
                }
                let alive = match self.client_mut(cidx, cgen) {
                    Some(conn) => {
                        note_outbound(conn);
                        conn.out.extend_from_slice(line.as_bytes());
                        conn.out.push(b'\n');
                        drives.push((cidx, cgen));
                        true
                    }
                    None => false,
                };
                if bin > 0 {
                    uc.bin_remaining = bin;
                    uc.bin_to_client = alive;
                } else {
                    self.complete_head(widx, uc, None, drives);
                }
                false
            }
            Head::Agg => match Json::parse(line) {
                Ok(v) => {
                    self.complete_head(widx, uc, Some(v), drives);
                    false
                }
                Err(_) => true,
            },
        }
    }

    /// The FIFO head's reply is fully relayed (or, for a fan-out leg,
    /// parsed): retire it.
    fn complete_head(
        &mut self,
        widx: usize,
        uc: &mut UpstreamConn,
        agg_value: Option<Json>,
        drives: &mut Vec<(u32, u32)>,
    ) {
        uc.bin_to_client = false;
        match uc.fifo.pop_front() {
            Some(Route::Client { idx, gen, .. }) => {
                self.stats.forwarded += 1;
                self.stats.per_worker[widx].forwarded += 1;
                if let Some(conn) = self.client_mut(idx, gen) {
                    conn.pending = false;
                    drives.push((idx, gen));
                }
            }
            Some(Route::Agg { id, widx: awidx }) => {
                self.agg_record(id, awidx, agg_value, Some(widx), drives);
            }
            None => {}
        }
    }

    /// Record one fan-out leg's result; finalize the merge when the last
    /// leg lands. `live` marks a worker slot whose connection is
    /// momentarily checked out of the pool (it must still read as up).
    fn agg_record(
        &mut self,
        id: u64,
        widx: usize,
        value: Option<Json>,
        live: Option<usize>,
        drives: &mut Vec<(u32, u32)>,
    ) {
        let Some(agg) = self.aggs.get_mut(&id) else { return };
        agg.results[widx] = value;
        agg.outstanding -= 1;
        if agg.outstanding > 0 {
            return;
        }
        let agg = self.aggs.remove(&id).expect("agg present");
        let reply = self.finalize_kind(agg.kind, &agg.results, live);
        let (cidx, cgen) = agg.client;
        if let Some(conn) = self.client_mut(cidx, cgen) {
            note_outbound(conn);
            conn.out.extend_from_slice(reply.to_string().as_bytes());
            conn.out.push(b'\n');
            conn.pending = false;
            drives.push((cidx, cgen));
        }
    }

    fn worker_views(&self, live: Option<usize>) -> Vec<WorkerView> {
        self.upstreams
            .iter()
            .enumerate()
            .map(|(i, u)| WorkerView { addr: u.name.clone(), up: u.up() || Some(i) == live })
            .collect()
    }

    fn finalize_kind(&self, kind: CmdKind, results: &[Option<Json>], live: Option<usize>) -> Json {
        match kind {
            CmdKind::Stats => stats::merge_stats(&self.stats, &self.worker_views(live), results),
            CmdKind::Health => stats::merge_health(&self.worker_views(live), results),
            CmdKind::Models => stats::merge_models(results),
        }
    }

    /// Fail every connection of one worker: open its breaker, tear the
    /// pool down, answer everything in flight.
    fn fail_worker(&mut self, widx: usize, drives: &mut Vec<(u32, u32)>) {
        self.upstreams[widx].breaker.on_failure();
        for pidx in 0..self.upstreams[widx].conns.len() {
            if let Some(uc) = self.upstreams[widx].conns[pidx].take() {
                let _ = self.poller.deregister(uc.stream.as_raw_fd());
                self.fail_conn_routes(widx, uc, drives);
            }
        }
    }

    /// Answer every FIFO entry of a dead connection: proxied submits get
    /// the `upstream unavailable` error (or a teardown, if their binary
    /// payload was already part-delivered), fan-out legs record `None`.
    fn fail_conn_routes(
        &mut self,
        widx: usize,
        mut uc: UpstreamConn,
        drives: &mut Vec<(u32, u32)>,
    ) {
        let name = self.upstreams[widx].name.clone();
        let mut mid_payload = uc.bin_remaining > 0 && uc.bin_to_client;
        while let Some(route) = uc.fifo.pop_front() {
            match route {
                Route::Client { idx, gen, model } => {
                    self.stats.upstream_errors += 1;
                    self.stats.per_worker[widx].upstream_errors += 1;
                    *self.stats.per_model_errors.entry(model.clone()).or_insert(0) += 1;
                    if mid_payload {
                        // Part of this reply's binary payload is already
                        // on the client's stream; an error line here would
                        // corrupt it, not help. Cut the connection.
                        self.teardown_client(idx, gen);
                    } else if let Some(conn) = self.client_mut(idx, gen) {
                        note_outbound(conn);
                        wire::error_reply(
                            &mut conn.out,
                            &format!(
                                "upstream unavailable: worker {name} failed (model '{model}')"
                            ),
                        );
                        conn.pending = false;
                        drives.push((idx, gen));
                    }
                }
                Route::Agg { id, widx: awidx } => {
                    self.agg_record(id, awidx, None, None, drives)
                }
            }
            mid_payload = false;
        }
    }

    /// Client hygiene sweep — identical policy to the worker frontend.
    /// Upstream connections are exempt: requests parked at a worker have
    /// no router-side deadline (see the module doc), and a worker that
    /// stops reading shows up as a connection failure soon enough.
    fn sweep(&mut self) {
        let now = Instant::now();
        let mut doomed: Vec<u32> = Vec::new();
        for (i, slot) in self.conns.iter().enumerate() {
            let Some(conn) = slot else { continue };
            let backlog = conn.out.len() - conn.written;
            let write_stalled = backlog > 0
                && now.duration_since(conn.last_write_progress) > self.opts.write_timeout;
            let mid_line = !conn.pending
                && !conn.eof
                && backlog == 0
                && !conn.buf.is_empty()
                && !conn.buf.contains(&b'\n');
            let read_stalled = mid_line
                && now.duration_since(conn.last_read_progress) > self.opts.read_timeout;
            if write_stalled || read_stalled {
                doomed.push(i as u32);
            }
        }
        for idx in doomed {
            if let Some(conn) = self.conns[idx as usize].take() {
                self.drop_client(idx, conn);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_banner_parses_and_rejects() {
        let addr = parse_serve_banner("deis serving on 127.0.0.1:7878 (models: gmm2d)\n");
        assert_eq!(addr, Some("127.0.0.1:7878".parse().unwrap()));
        let addr = parse_serve_banner("deis serving on 0.0.0.0:80 (models: a,b)");
        assert_eq!(addr, Some("0.0.0.0:80".parse().unwrap()));
        assert_eq!(parse_serve_banner("deis router on 127.0.0.1:1 (workers: x)"), None);
        assert_eq!(parse_serve_banner("deis serving on not-an-addr (models: m)"), None);
        assert_eq!(parse_serve_banner(""), None);
    }

    #[test]
    fn route_scan_extracts_the_model_and_nothing_else() {
        assert_eq!(
            route_scan(r#"{"model":"gmm2d","solver":"tab3","nfe":10,"n":4}"#),
            Scan::Submit("gmm2d".to_string())
        );
        // Last-wins duplicates, matching the fast submit parser.
        assert_eq!(
            route_scan(r#"{"model":"a","model":"b"}"#),
            Scan::Submit("b".to_string())
        );
        // No model: routed under "" — the WORKER owns the error text.
        assert_eq!(route_scan(r#"{"solver":"tab3"}"#), Scan::Submit(String::new()));
        // Commands, escapes and malformed lines fall back to the tree.
        assert_eq!(route_scan(r#"{"cmd":"stats"}"#), Scan::Tree);
        assert_eq!(route_scan(r#"{"model":"a\"b"}"#), Scan::Tree);
        assert_eq!(route_scan(r#"{"model":"a""#), Scan::Tree);
        assert_eq!(route_scan("not json"), Scan::Tree);
    }

    #[test]
    fn tokens_pack_and_unpack() {
        let t = client_token(7, 0x7FFF_FFFF);
        assert_eq!(t & UPSTREAM_BIT, 0);
        assert_eq!((t & 0xFFFF_FFFF) as u32, 7);
        assert_eq!(((t >> 32) & GEN_MASK as u64) as u32, 0x7FFF_FFFF);
        let t = upstream_token(3, 5, 0x7FFF_FFFF);
        assert_ne!(t & UPSTREAM_BIT, 0);
        assert_eq!(((t >> 16) & 0xFFFF) as usize, 3);
        assert_eq!((t & 0xFFFF) as usize, 5);
        assert_eq!(((t >> 32) & GEN_MASK as u64) as u32, 0x7FFF_FFFF);
        assert_ne!(client_token(0, 0), LISTENER_TOKEN);
        // The maximum REACHABLE packed token: serve_with admits at most
        // 65535 workers (widx <= 0xFFFE) and clamps the pool to 65535
        // slots (pidx <= 0xFFFE), which is exactly what keeps the
        // all-ones LISTENER_TOKEN out of the packed-token space.
        assert_ne!(upstream_token(0xFFFE, 0xFFFE, GEN_MASK), LISTENER_TOKEN);
    }

    #[test]
    fn serve_refuses_an_empty_upstream_list() {
        assert!(serve(Vec::new(), "127.0.0.1:0").is_err());
        assert!(serve(vec!["definitely-not-resolvable.invalid:1".into()], "127.0.0.1:0").is_err());
    }

    #[test]
    fn serve_refuses_duplicate_upstreams() {
        // Duplicates would double-count fan-out merges; rejected up front.
        let ups = vec!["127.0.0.1:7001".to_string(), "127.0.0.1:7001".to_string()];
        let err = serve(ups, "127.0.0.1:0").unwrap_err();
        assert!(err.to_string().contains("duplicate upstream"), "{err:#}");
    }
}
