//! Rust-native forward pass of the trained eps-net (weights_*.json).
//!
//! Mirrors python/compile/model.py::apply_eps exactly (same sinusoidal
//! embedding, same tanh-GELU). Used to (a) cross-check PJRT numerics against
//! an independent implementation (checks_*.json fixtures) and (b) drive the
//! big table sweeps without PJRT dispatch overhead.

use anyhow::{Context, Result};

use crate::score::EpsModel;
use crate::tensor::{add_bias_inplace, add_inplace, gelu_inplace, matmul_bias_into, Mat};
use crate::util::json::Json;

const TIME_SCALE: f64 = 1000.0; // keep in sync with kernels/ref.py

struct Block {
    w1: Mat,
    b1: Vec<f64>,
    u: Mat,
    w2: Mat,
    b2: Vec<f64>,
}

pub struct NativeMlp {
    dim: usize,
    embed: usize,
    w_in: Mat,
    b_in: Vec<f64>,
    w_out: Mat,
    b_out: Vec<f64>,
    blocks: Vec<Block>,
    freqs: Vec<f64>,
}

impl NativeMlp {
    pub fn load(path: &str) -> Result<NativeMlp> {
        let root = Json::from_file(path)?;
        Self::from_json(&root).with_context(|| format!("weights file {path}"))
    }

    pub fn from_json(root: &Json) -> Result<NativeMlp> {
        let dim = root.get("dim")?.as_usize()?;
        let embed = root.get("embed")?.as_usize()?;
        let p = root.get("params")?;
        let mat = |v: &Json| -> Result<Mat> {
            let (r, c, data) = v.as_matrix()?;
            Ok(Mat::from_rows(r, c, data))
        };
        let mut blocks = Vec::new();
        for blk in p.get("blocks")?.as_arr()? {
            blocks.push(Block {
                w1: mat(blk.get("w1")?)?,
                b1: blk.get("b1")?.as_f64_vec()?,
                u: mat(blk.get("u")?)?,
                w2: mat(blk.get("w2")?)?,
                b2: blk.get("b2")?.as_f64_vec()?,
            });
        }
        let half = embed / 2;
        let freqs = (0..half)
            .map(|i| (-(10000.0f64).ln() * i as f64 / half as f64).exp())
            .collect();
        Ok(NativeMlp {
            dim,
            embed,
            w_in: mat(p.get("w_in")?)?,
            b_in: p.get("b_in")?.as_f64_vec()?,
            w_out: mat(p.get("w_out")?)?,
            b_out: p.get("b_out")?.as_f64_vec()?,
            blocks,
            freqs,
        })
    }

    pub fn hidden(&self) -> usize {
        self.w_in.cols
    }

    fn time_embed(&self, t: &[f64]) -> Mat {
        let half = self.embed / 2;
        let mut e = Mat::zeros(t.len(), self.embed);
        for (r, &tv) in t.iter().enumerate() {
            let row = e.row_mut(r);
            for (i, &f) in self.freqs.iter().enumerate() {
                let ang = TIME_SCALE * tv * f;
                row[i] = ang.sin();
                row[half + i] = ang.cos();
            }
        }
        e
    }
}

impl NativeMlp {
    /// Full forward for a contiguous slice of the batch (single-threaded).
    fn forward_rows(&self, x: &[f64], t: &[f64], b: usize, out: &mut [f64]) {
        let xm = Mat::from_rows(b, self.dim, x.to_vec());
        let e = self.time_embed(t);
        let h_dim = self.hidden();
        let mut h = Mat::zeros(b, h_dim);
        matmul_bias_into(&xm, &self.w_in, &self.b_in, &mut h);
        let zero_bias = vec![0.0; h_dim];
        let mut z = Mat::zeros(b, h_dim);
        let mut zu = Mat::zeros(b, h_dim);
        let mut upd = Mat::zeros(b, h_dim);
        for blk in &self.blocks {
            // z = h @ w1 + b1 + e @ u
            matmul_bias_into(&h, &blk.w1, &blk.b1, &mut z);
            matmul_bias_into(&e, &blk.u, &zero_bias, &mut zu);
            add_inplace(&mut z, &zu);
            gelu_inplace(&mut z);
            // h += gelu(z) @ w2 + b2
            matmul_bias_into(&z, &blk.w2, &blk.b2, &mut upd);
            add_inplace(&mut h, &upd);
        }
        let mut o = Mat::zeros(b, self.dim);
        matmul_bias_into(&h, &self.w_out, &self.b_out, &mut o);
        out.copy_from_slice(&o.data);
        let _ = add_bias_inplace; // (kept for symmetry; bias handled in matmul)
    }
}

impl EpsModel for NativeMlp {
    fn dim(&self) -> usize {
        self.dim
    }

    fn eval(&self, x: &[f64], t: &[f64], b: usize, out: &mut [f64]) {
        // Batch rows are independent: fan the whole forward out across
        // scoped threads ONCE per eval (one spawn set amortized over the
        // full 9-matmul chain — §Perf iteration 2).
        let d = self.dim;
        let flops = 2 * b * self.hidden() * self.hidden() * (2 * self.blocks.len() + 1);
        let threads = if flops > 1 << 22 {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1).min(8)
        } else {
            1
        };
        if threads <= 1 || b < 2 * threads {
            self.forward_rows(x, t, b, out);
            return;
        }
        let chunk_rows = b.div_ceil(threads);
        std::thread::scope(|s| {
            let mut rest = &mut *out;
            let mut row0 = 0;
            while row0 < b {
                let rows = chunk_rows.min(b - row0);
                let (head, tail) = rest.split_at_mut(rows * d);
                rest = tail;
                let xs = &x[row0 * d..(row0 + rows) * d];
                let ts = &t[row0..row0 + rows];
                s.spawn(move || self.forward_rows(xs, ts, rows, head));
                row0 += rows;
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hand-built one-block net with identity-ish weights; oracle computed
    /// by transcribing the python math by hand.
    #[test]
    fn forward_matches_hand_computation() {
        let json = r#"{
          "dim": 1, "hidden": 2, "embed": 2, "n_blocks": 1,
          "params": {
            "w_in": [[1.0, 2.0]], "b_in": [0.1, -0.1],
            "w_out": [[1.0], [1.0]], "b_out": [0.5],
            "blocks": [{
              "w1": [[1.0, 0.0], [0.0, 1.0]], "b1": [0.0, 0.0],
              "u":  [[0.0, 0.0], [0.0, 0.0]],
              "w2": [[1.0, 0.0], [0.0, 1.0]], "b2": [0.0, 0.0]
            }]
          }
        }"#;
        let net = NativeMlp::from_json(&Json::parse(json).unwrap()).unwrap();
        let x = [2.0];
        let t = [0.0];
        let mut out = [0.0];
        net.eval(&x, &t, 1, &mut out);
        // h = [2.1, 3.9]; block: h + gelu(h) = [2.1+gelu(2.1), 3.9+gelu(3.9)]
        let g = |v: f64| crate::tensor::gelu(v);
        let want = (2.1 + g(2.1)) + (3.9 + g(3.9)) + 0.5;
        assert!((out[0] - want).abs() < 1e-12, "{} vs {}", out[0], want);
    }

    #[test]
    fn time_embed_matches_formula() {
        let json = r#"{
          "dim": 1, "hidden": 1, "embed": 4, "n_blocks": 0,
          "params": {"w_in": [[1.0]], "b_in": [0.0], "w_out": [[1.0]],
                     "b_out": [0.0], "blocks": []}
        }"#;
        let net = NativeMlp::from_json(&Json::parse(json).unwrap()).unwrap();
        let e = net.time_embed(&[0.001]);
        // freqs = [1, exp(-ln(1e4)/2)] = [1, 0.01]; ang = [1.0, 0.01]
        assert!((e.data[0] - 1.0f64.sin()).abs() < 1e-12);
        assert!((e.data[1] - 0.01f64.sin()).abs() < 1e-12);
        assert!((e.data[2] - 1.0f64.cos()).abs() < 1e-12);
        assert!((e.data[3] - 0.01f64.cos()).abs() < 1e-12);
    }
}
