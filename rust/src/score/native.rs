//! Rust-native forward pass of the trained eps-net (weights_*.json).
//!
//! Mirrors python/compile/model.py::apply_eps exactly (same sinusoidal
//! embedding, same tanh-GELU). Used to (a) cross-check PJRT numerics against
//! an independent implementation (checks_*.json fixtures) and (b) drive the
//! big table sweeps and the serving hot path without PJRT dispatch overhead.
//!
//! §Perf iteration 3 (EXPERIMENTS.md): the forward is a zero-allocation
//! engine in the steady state.
//!
//!   * Batch chunks fan out over the persistent [`crate::score::pool`]
//!     worker pool instead of spawning a `thread::scope` thread set on
//!     every eval (i.e. on every solver step of every batch).
//!   * Every activation lives in a per-thread [`Scratch`] workspace reused
//!     across solver steps (the old code did ~6 `Mat::zeros` plus an
//!     `x.to_vec()` per chunk per eval).
//!   * Uniform-t fast path: solver stepping broadcasts a scalar t, so the
//!     time-embedding row and every per-block `e @ u` product are
//!     row-identical. They are computed once per eval into a
//!     [`UniformScratch`] and folded into each block's first bias, deleting
//!     one of the two matmuls per residual block; the GELU epilogue is
//!     fused into the remaining one ([`Kernel::overwrite_gelu`]).
//!
//! §Kernels (this PR): the engine is generic over the tensor
//! [`Element`] type. [`NativeMlp`] wraps an f64 or an f32 [`MlpCore`]
//! chosen at weight-load time via [`Precision`]:
//!
//!   * **f64** (default) — bit-compatible with the python oracle and with
//!     the pre-generic engine (pinned by `tests/kernel_paths.rs`).
//!   * **f32** (opt-in, `--precision f32` / `"dtype":"f32"`) — weights are
//!     narrowed once at load; each eval narrows x/t and widens the eps
//!     output through thread-local [`Conv`] buffers, so `EpsModel` (and
//!     therefore every solver and the whole scheduler) stays f64 and the
//!     steady state stays allocation-free. Embedding angles are still
//!     computed in f64 (sin/cos of large `TIME_SCALE * t` angles lose real
//!     precision in f32) and then narrowed. Tolerance story:
//!     EXPERIMENTS.md §Kernels; parity pinned by
//!     `tests/precision_parity.rs`.
//!
//! `rust/tests/zero_alloc.rs` pins the no-steady-state-allocation claim for
//! both precisions with a counting global allocator.

use std::cell::RefCell;

use anyhow::{Context, Result};

use crate::score::pool::WorkerPool;
use crate::score::EpsModel;
use crate::tensor::{Element, Kernel, Mat};
use crate::util::json::Json;

const TIME_SCALE: f64 = 1000.0; // keep in sync with kernels/ref.py

/// Flop threshold above which an eval fans out to the worker pool (below
/// it, dispatch overhead dominates the matmul work).
const PARALLEL_FLOPS: usize = 1 << 22;

/// Inference precision of a native eps-net engine. `F64` is the default
/// and the numeric reference; `F32` trades ~half the mantissa for ~2x the
/// SIMD width on the hot kernels.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, Default)]
pub enum Precision {
    #[default]
    F64,
    F32,
}

impl Precision {
    /// Parse a wire/CLI dtype name ("f64" / "f32").
    pub fn parse(s: &str) -> Option<Precision> {
        match s {
            "f64" => Some(Precision::F64),
            "f32" => Some(Precision::F32),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Precision::F64 => "f64",
            Precision::F32 => "f32",
        }
    }
}

struct Block<E: Element> {
    w1: Mat<E>,
    b1: Vec<E>,
    u: Mat<E>,
    w2: Mat<E>,
    b2: Vec<E>,
}

/// The eps-net engine at one concrete precision. All the math lives here;
/// [`NativeMlp`] is the precision-erased wrapper the registry stores.
struct MlpCore<E: Element> {
    dim: usize,
    embed: usize,
    w_in: Mat<E>,
    b_in: Vec<E>,
    w_out: Mat<E>,
    b_out: Vec<E>,
    blocks: Vec<Block<E>>,
    /// Embedding frequencies stay f64 at every precision: the sinusoid
    /// arguments (`TIME_SCALE * t * freq`) are large, so angle precision
    /// matters more than multiply throughput (this is O(embed) per eval,
    /// not a hot loop).
    freqs: Vec<f64>,
    /// All-zero [hidden] bias for accumulate-only matmuls (generic-t path).
    zero_bias: Vec<E>,
}

/// Per-thread activation arena. Buffers are length-adjusted in place (no
/// reallocation once capacity covers the working shape) and fully written
/// before they are read, so reuse across differing (b, dim) shapes can
/// never leak stale data — a property test below pins that.
#[derive(Default)]
struct Scratch<E: Element> {
    /// [b, hidden] residual stream.
    h: Vec<E>,
    /// [b, hidden] block pre-activation.
    z: Vec<E>,
    /// [b, embed] per-row time embedding (generic-t path only).
    e: Vec<E>,
}

/// Per-eval uniform-t precompute: one embedding row and one combined
/// `b1 + e @ u` bias per block, shared read-only by every chunk task.
#[derive(Default)]
struct UniformScratch<E: Element> {
    e_row: Vec<E>,
    /// [n_blocks, hidden], block-major.
    block_bias: Vec<E>,
}

/// Borrowed view of the uniform-t precompute handed to chunk tasks.
#[derive(Clone, Copy)]
struct UniformCtx<'a, E: Element> {
    /// [n_blocks, hidden] combined first-layer biases.
    block_bias: &'a [E],
}

/// f64 ↔ f32 boundary buffers for the f32 engine: `EpsModel::eval` speaks
/// f64 slices, so each eval narrows x/t once and widens the output once.
/// Thread-local and length-managed like [`Scratch`], keeping the steady
/// state allocation-free.
#[derive(Default)]
struct Conv {
    x: Vec<f32>,
    t: Vec<f32>,
    out: Vec<f32>,
}

thread_local! {
    /// Chunk-forward workspaces, owned by whichever thread runs the chunk
    /// (pool workers and dispatching callers alike) — one per precision,
    /// routed through [`NativeElement`].
    static SCRATCH_F64: RefCell<Scratch<f64>> = RefCell::new(Scratch::default());
    static SCRATCH_F32: RefCell<Scratch<f32>> = RefCell::new(Scratch::default());
    /// Uniform-t precompute. Only the dispatching thread touches it; it is
    /// a separate thread-local from SCRATCH because the dispatcher holds
    /// the ctx borrow while itself executing chunk tasks (which need
    /// SCRATCH mutably).
    static UNIFORM_F64: RefCell<UniformScratch<f64>> = RefCell::new(UniformScratch::default());
    static UNIFORM_F32: RefCell<UniformScratch<f32>> = RefCell::new(UniformScratch::default());
    /// f32-engine boundary buffers. Only the dispatching thread touches
    /// them (chunk tasks read the already-narrowed slices), so like UNIFORM
    /// they stay separate from SCRATCH.
    static CONV: RefCell<Conv> = RefCell::new(Conv::default());
}

/// Private per-precision plumbing: generic code cannot name a
/// `thread_local!` per monomorphization, so each element type routes to
/// its own workspace statics.
trait NativeElement: Element {
    fn with_scratch<R>(f: impl FnOnce(&mut Scratch<Self>) -> R) -> R;
    fn with_uniform<R>(f: impl FnOnce(&mut UniformScratch<Self>) -> R) -> R;
}

impl NativeElement for f64 {
    fn with_scratch<R>(f: impl FnOnce(&mut Scratch<f64>) -> R) -> R {
        SCRATCH_F64.with(|s| f(&mut s.borrow_mut()))
    }

    fn with_uniform<R>(f: impl FnOnce(&mut UniformScratch<f64>) -> R) -> R {
        UNIFORM_F64.with(|u| f(&mut u.borrow_mut()))
    }
}

impl NativeElement for f32 {
    fn with_scratch<R>(f: impl FnOnce(&mut Scratch<f32>) -> R) -> R {
        SCRATCH_F32.with(|s| f(&mut s.borrow_mut()))
    }

    fn with_uniform<R>(f: impl FnOnce(&mut UniformScratch<f32>) -> R) -> R {
        UNIFORM_F32.with(|u| f(&mut u.borrow_mut()))
    }
}

/// Adjust a workspace buffer's length, reusing capacity (new elements are
/// zeroed; retained elements keep whatever the previous use left — callers
/// fully overwrite before reading).
#[inline]
fn set_len<E: Element>(buf: &mut Vec<E>, len: usize) {
    buf.resize(len, E::ZERO);
}

/// Raw-pointer wrapper so chunk tasks can carve disjoint output windows
/// through a shared `Fn` closure.
struct SendPtr<E>(*mut E);
impl<E> Clone for SendPtr<E> {
    fn clone(&self) -> Self {
        SendPtr(self.0)
    }
}
impl<E> Copy for SendPtr<E> {}
unsafe impl<E> Send for SendPtr<E> {}
unsafe impl<E> Sync for SendPtr<E> {}

impl<E: NativeElement> MlpCore<E> {
    fn from_json(root: &Json) -> Result<MlpCore<E>> {
        let dim = root.get("dim")?.as_usize()?;
        let embed = root.get("embed")?.as_usize()?;
        let p = root.get("params")?;
        let mat = |v: &Json| -> Result<Mat<E>> {
            let (r, c, data) = v.as_matrix()?;
            Ok(Mat::from_f64_rows(r, c, &data))
        };
        let evec = |v: &Json| -> Result<Vec<E>> {
            Ok(v.as_f64_vec()?.iter().map(|&x| E::from_f64(x)).collect())
        };
        let mut blocks = Vec::new();
        for blk in p.get("blocks")?.as_arr()? {
            blocks.push(Block {
                w1: mat(blk.get("w1")?)?,
                b1: evec(blk.get("b1")?)?,
                u: mat(blk.get("u")?)?,
                w2: mat(blk.get("w2")?)?,
                b2: evec(blk.get("b2")?)?,
            });
        }
        let half = embed / 2;
        let freqs = (0..half)
            .map(|i| (-(10000.0f64).ln() * i as f64 / half as f64).exp())
            .collect();
        let w_in = mat(p.get("w_in")?)?;
        let zero_bias = vec![E::ZERO; w_in.cols];
        Ok(MlpCore {
            dim,
            embed,
            w_in,
            b_in: evec(p.get("b_in")?)?,
            w_out: mat(p.get("w_out")?)?,
            b_out: evec(p.get("b_out")?)?,
            blocks,
            freqs,
            zero_bias,
        })
    }

    fn hidden(&self) -> usize {
        self.w_in.cols
    }

    /// Sinusoidal embedding of one scalar t into `row` ([embed]). Angles
    /// are computed in f64 regardless of E (see `freqs`).
    fn time_embed_row(&self, t: f64, row: &mut [E]) {
        let half = self.embed / 2;
        for (i, &f) in self.freqs.iter().enumerate() {
            let ang = TIME_SCALE * t * f;
            row[i] = E::from_f64(ang.sin());
            row[half + i] = E::from_f64(ang.cos());
        }
    }

    /// Uniform-t precompute: embedding row once, then fold `e @ u` into each
    /// block's first-layer bias (`bias_j = b1_j + e_row @ u_j`).
    fn build_uniform_ctx<'a>(
        &self,
        t: f64,
        uni: &'a mut UniformScratch<E>,
    ) -> UniformCtx<'a, E> {
        set_len(&mut uni.e_row, self.embed);
        if self.embed % 2 == 1 {
            // Odd embed: the element past the sin/cos halves is never
            // written by time_embed_row.
            uni.e_row.fill(E::ZERO);
        }
        self.time_embed_row(t, &mut uni.e_row);
        let hd = self.hidden();
        set_len(&mut uni.block_bias, self.blocks.len() * hd);
        uni.block_bias.fill(E::ZERO); // accumulating kernel adds on top
        let UniformScratch { e_row, block_bias } = uni;
        for (j, blk) in self.blocks.iter().enumerate() {
            Kernel::accumulate().run(
                &e_row[..],
                self.embed,
                &blk.u,
                &blk.b1,
                &mut block_bias[j * hd..(j + 1) * hd],
            );
        }
        UniformCtx { block_bias: &block_bias[..] }
    }

    /// Forward for `b` contiguous rows on the current thread. With a
    /// uniform-t `ctx` the per-block update is two fused matmuls
    /// (`gelu(h @ w1 + bias_j)` and `h += z @ w2 + b2`); without it, the
    /// per-row embedding and `e @ u` matmul run as in the generic math.
    fn forward_rows(
        &self,
        x: &[E],
        t: Option<&[E]>,
        b: usize,
        out: &mut [E],
        scr: &mut Scratch<E>,
        ctx: Option<UniformCtx<'_, E>>,
    ) {
        let hd = self.hidden();
        set_len(&mut scr.h, b * hd);
        Kernel::overwrite().run(x, self.dim, &self.w_in, &self.b_in, &mut scr.h);
        set_len(&mut scr.z, b * hd);
        match ctx {
            Some(c) => {
                for (j, blk) in self.blocks.iter().enumerate() {
                    let bias = &c.block_bias[j * hd..(j + 1) * hd];
                    // z = gelu(h @ w1 + (b1 + e @ u)), GELU in the epilogue.
                    Kernel::overwrite_gelu().run(&scr.h, hd, &blk.w1, bias, &mut scr.z);
                    // h += z @ w2 + b2, residual add in the epilogue.
                    Kernel::accumulate().run(&scr.z, hd, &blk.w2, &blk.b2, &mut scr.h);
                }
            }
            None => {
                let t = t.expect("generic path needs per-row t");
                set_len(&mut scr.e, b * self.embed);
                if self.embed % 2 == 1 {
                    scr.e.fill(E::ZERO);
                }
                for (r, &tv) in t.iter().enumerate() {
                    self.time_embed_row(
                        tv.to_f64(),
                        &mut scr.e[r * self.embed..(r + 1) * self.embed],
                    );
                }
                for blk in &self.blocks {
                    // z = h @ w1 + b1, then z = gelu(z + e @ u + 0) with the
                    // GELU fused into the accumulating kernel's epilogue
                    // (what used to be a separate gelu_slice pass).
                    Kernel::overwrite().run(&scr.h, hd, &blk.w1, &blk.b1, &mut scr.z);
                    Kernel::accumulate_gelu().run(
                        &scr.e,
                        self.embed,
                        &blk.u,
                        &self.zero_bias,
                        &mut scr.z,
                    );
                    Kernel::accumulate().run(&scr.z, hd, &blk.w2, &blk.b2, &mut scr.h);
                }
            }
        }
        Kernel::overwrite().run(&scr.h, hd, &self.w_out, &self.b_out, out);
    }

    /// Split the batch into `n_chunks` row ranges and run them across the
    /// pool (the calling thread participates; with `n_chunks == 1` it runs
    /// the whole batch inline).
    #[allow(clippy::too_many_arguments)]
    fn run_chunks(
        &self,
        x: &[E],
        t: Option<&[E]>,
        b: usize,
        out: &mut [E],
        n_chunks: usize,
        ctx: Option<UniformCtx<'_, E>>,
        pool: &WorkerPool,
    ) {
        let d = self.dim;
        if n_chunks <= 1 {
            E::with_scratch(|scr| self.forward_rows(x, t, b, out, scr, ctx));
            return;
        }
        let chunk_rows = b.div_ceil(n_chunks);
        let nc = b.div_ceil(chunk_rows);
        let optr = SendPtr(out.as_mut_ptr());
        let task = move |ci: usize| {
            let row0 = ci * chunk_rows;
            let rows = chunk_rows.min(b - row0);
            // Disjoint window: chunk ci owns rows [row0, row0 + rows).
            let o = unsafe { std::slice::from_raw_parts_mut(optr.0.add(row0 * d), rows * d) };
            let xs = &x[row0 * d..(row0 + rows) * d];
            let ts = t.map(|tt| &tt[row0..row0 + rows]);
            E::with_scratch(|scr| self.forward_rows(xs, ts, rows, o, scr, ctx));
        };
        pool.run(nc, &task);
    }

    /// Full eval at this precision: uniform-t detection, flop-gated pool
    /// fan-out, per-chunk forward.
    fn eval(&self, x: &[E], t: &[E], b: usize, out: &mut [E]) {
        let d = self.dim;
        assert_eq!(x.len(), b * d);
        assert_eq!(t.len(), b);
        assert_eq!(out.len(), b * d);
        if b == 0 {
            return;
        }
        let pool = WorkerPool::global();
        let flops = 2 * b * self.hidden() * self.hidden() * (2 * self.blocks.len() + 1);
        let par = if flops > PARALLEL_FLOPS { pool.threads() } else { 1 };
        let n_chunks = if par <= 1 || b < 2 * par { 1 } else { par };
        // Solver stepping broadcasts a scalar t; detect it and take the
        // shared-embedding fast path.
        if t.iter().all(|&tv| tv == t[0]) {
            E::with_uniform(|uni| {
                let ctx = self.build_uniform_ctx(t[0].to_f64(), uni);
                self.run_chunks(x, None, b, out, n_chunks, Some(ctx), pool);
            });
        } else {
            self.run_chunks(x, Some(t), b, out, n_chunks, None, pool);
        }
    }
}

impl MlpCore<f32> {
    /// f64-at-the-boundary eval: narrow x/t into the thread-local [`Conv`]
    /// buffers, run the f32 engine, widen the output. Solvers and the
    /// scheduler never see an f32 value.
    fn eval_widening(&self, x: &[f64], t: &[f64], b: usize, out: &mut [f64]) {
        CONV.with(|c| {
            let conv = &mut *c.borrow_mut();
            set_len(&mut conv.x, x.len());
            for (dst, &src) in conv.x.iter_mut().zip(x) {
                *dst = src as f32;
            }
            set_len(&mut conv.t, t.len());
            for (dst, &src) in conv.t.iter_mut().zip(t) {
                *dst = src as f32;
            }
            set_len(&mut conv.out, out.len());
            self.eval(&conv.x, &conv.t, b, &mut conv.out);
            for (dst, &src) in out.iter_mut().zip(&conv.out) {
                *dst = src as f64;
            }
        });
    }
}

/// Precision-erased native eps-net. The registry (and every `EpsModel`
/// consumer) holds this; the precision is fixed when the weights are
/// loaded.
pub struct NativeMlp {
    repr: Repr,
}

enum Repr {
    F64(MlpCore<f64>),
    F32(MlpCore<f32>),
}

impl NativeMlp {
    pub fn load(path: &str) -> Result<NativeMlp> {
        Self::load_with(path, Precision::F64)
    }

    pub fn load_with(path: &str, precision: Precision) -> Result<NativeMlp> {
        let root = Json::from_file(path)?;
        Self::from_json_with(&root, precision).with_context(|| format!("weights file {path}"))
    }

    pub fn from_json(root: &Json) -> Result<NativeMlp> {
        Self::from_json_with(root, Precision::F64)
    }

    /// Parse weights (always stored as f64 JSON) into an engine at the
    /// requested inference precision; f32 narrows once here.
    pub fn from_json_with(root: &Json, precision: Precision) -> Result<NativeMlp> {
        let repr = match precision {
            Precision::F64 => Repr::F64(MlpCore::from_json(root)?),
            Precision::F32 => Repr::F32(MlpCore::from_json(root)?),
        };
        Ok(NativeMlp { repr })
    }

    pub fn precision(&self) -> Precision {
        match self.repr {
            Repr::F64(_) => Precision::F64,
            Repr::F32(_) => Precision::F32,
        }
    }

    pub fn hidden(&self) -> usize {
        match &self.repr {
            Repr::F64(core) => core.hidden(),
            Repr::F32(core) => core.hidden(),
        }
    }
}

impl EpsModel for NativeMlp {
    fn dim(&self) -> usize {
        match &self.repr {
            Repr::F64(core) => core.dim,
            Repr::F32(core) => core.dim,
        }
    }

    fn eval(&self, x: &[f64], t: &[f64], b: usize, out: &mut [f64]) {
        match &self.repr {
            Repr::F64(core) => core.eval(x, t, b, out),
            Repr::F32(core) => core.eval_widening(x, t, b, out),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_close, run_prop};
    use crate::util::rng::Rng;

    /// Hand-built one-block net with identity-ish weights; oracle computed
    /// by transcribing the python math by hand.
    #[test]
    fn forward_matches_hand_computation() {
        let json = r#"{
          "dim": 1, "hidden": 2, "embed": 2, "n_blocks": 1,
          "params": {
            "w_in": [[1.0, 2.0]], "b_in": [0.1, -0.1],
            "w_out": [[1.0], [1.0]], "b_out": [0.5],
            "blocks": [{
              "w1": [[1.0, 0.0], [0.0, 1.0]], "b1": [0.0, 0.0],
              "u":  [[0.0, 0.0], [0.0, 0.0]],
              "w2": [[1.0, 0.0], [0.0, 1.0]], "b2": [0.0, 0.0]
            }]
          }
        }"#;
        let net = NativeMlp::from_json(&Json::parse(json).unwrap()).unwrap();
        let x = [2.0];
        let t = [0.0];
        let mut out = [0.0];
        net.eval(&x, &t, 1, &mut out);
        // h = [2.1, 3.9]; block: h + gelu(h) = [2.1+gelu(2.1), 3.9+gelu(3.9)]
        let g = |v: f64| crate::tensor::gelu(v);
        let want = (2.1 + g(2.1)) + (3.9 + g(3.9)) + 0.5;
        assert!((out[0] - want).abs() < 1e-12, "{} vs {}", out[0], want);
    }

    #[test]
    fn time_embed_matches_formula() {
        let json = r#"{
          "dim": 1, "hidden": 1, "embed": 4, "n_blocks": 0,
          "params": {"w_in": [[1.0]], "b_in": [0.0], "w_out": [[1.0]],
                     "b_out": [0.0], "blocks": []}
        }"#;
        let net: MlpCore<f64> = MlpCore::from_json(&Json::parse(json).unwrap()).unwrap();
        let mut e = [0.0; 4];
        net.time_embed_row(0.001, &mut e);
        // freqs = [1, exp(-ln(1e4)/2)] = [1, 0.01]; ang = [1.0, 0.01]
        assert!((e[0] - 1.0f64.sin()).abs() < 1e-12);
        assert!((e[1] - 0.01f64.sin()).abs() < 1e-12);
        assert!((e[2] - 1.0f64.cos()).abs() < 1e-12);
        assert!((e[3] - 0.01f64.cos()).abs() < 1e-12);
    }

    #[test]
    fn precision_parse_and_name_roundtrip() {
        assert_eq!(Precision::parse("f64"), Some(Precision::F64));
        assert_eq!(Precision::parse("f32"), Some(Precision::F32));
        assert_eq!(Precision::parse("f16"), None);
        assert_eq!(Precision::default().name(), "f64");
        assert_eq!(Precision::F32.name(), "f32");
    }

    fn evec<E: Element>(v: Vec<f64>) -> Vec<E> {
        v.iter().map(|&x| E::from_f64(x)).collect()
    }

    fn rand_block<E: NativeElement>(rng: &mut Rng, hidden: usize, embed: usize) -> Block<E> {
        Block {
            w1: Mat::from_f64_rows(hidden, hidden, &rng.normal_vec(hidden * hidden)),
            b1: evec(rng.normal_vec(hidden)),
            u: Mat::from_f64_rows(embed, hidden, &rng.normal_vec(embed * hidden)),
            w2: Mat::from_f64_rows(hidden, hidden, &rng.normal_vec(hidden * hidden)),
            b2: evec(rng.normal_vec(hidden)),
        }
    }

    /// Deterministic random net: the same `rng` seed yields the same
    /// underlying f64 draws at any precision (f32 nets are narrowed from
    /// identical values — exactly like weight loading).
    fn rand_net<E: NativeElement>(
        rng: &mut Rng,
        dim: usize,
        hidden: usize,
        embed: usize,
        n_blocks: usize,
    ) -> MlpCore<E> {
        let half = embed / 2;
        MlpCore {
            dim,
            embed,
            w_in: Mat::from_f64_rows(dim, hidden, &rng.normal_vec(dim * hidden)),
            b_in: evec(rng.normal_vec(hidden)),
            w_out: Mat::from_f64_rows(hidden, dim, &rng.normal_vec(hidden * dim)),
            b_out: evec(rng.normal_vec(dim)),
            blocks: (0..n_blocks).map(|_| rand_block(rng, hidden, embed)).collect(),
            freqs: (0..half)
                .map(|i| (-(10000.0f64).ln() * i as f64 / half as f64).exp())
                .collect(),
            zero_bias: vec![E::ZERO; hidden],
        }
    }

    /// Reference forward with a brand-new workspace (no shared state).
    fn fresh_forward(net: &MlpCore<f64>, x: &[f64], t: &[f64], b: usize) -> Vec<f64> {
        let mut out = vec![0.0; b * net.dim];
        let mut scr = Scratch::default();
        net.forward_rows(x, Some(t), b, &mut out, &mut scr, None);
        out
    }

    #[test]
    fn pooled_matches_single_thread() {
        let mut rng = Rng::new(11);
        let net: MlpCore<f64> = rand_net(&mut rng, 3, 9, 6, 2);
        let b = 37; // odd: exercises the tail-row kernel and ragged chunks
        let x = rng.normal_vec(b * 3);
        let t: Vec<f64> = (0..b).map(|_| rng.uniform_in(0.01, 1.0)).collect();
        let pool = WorkerPool::global();
        let mut single = vec![0.0; b * 3];
        net.run_chunks(&x, Some(&t), b, &mut single, 1, None, pool);
        for n_chunks in [2, 3, 4, 7] {
            let mut pooled = vec![0.0; b * 3];
            net.run_chunks(&x, Some(&t), b, &mut pooled, n_chunks, None, pool);
            assert_close(&pooled, &single, 1e-12, "pooled vs single-thread forward");
        }
    }

    #[test]
    fn uniform_fast_path_matches_generic() {
        let mut rng = Rng::new(13);
        for (dim, hidden, embed, n_blocks) in [(2, 8, 4, 1), (3, 7, 5, 3), (1, 4, 2, 0)] {
            let net: MlpCore<f64> = rand_net(&mut rng, dim, hidden, embed, n_blocks);
            let b = 19;
            let x = rng.normal_vec(b * dim);
            let tv = rng.uniform_in(0.01, 1.0);
            let t = vec![tv; b];
            // eval() auto-detects the uniform t and takes the fast path.
            let mut fast = vec![0.0; b * dim];
            net.eval(&x, &t, b, &mut fast);
            let generic = fresh_forward(&net, &x, &t, b);
            assert_close(&fast, &generic, 1e-12, "uniform fast path vs generic");
        }
    }

    #[test]
    fn workspace_reuse_across_shapes_never_aliases_stale_data() {
        // Interleave evals of different (b, dim, hidden, embed) shapes on
        // one thread; the shared thread-local workspace must always produce
        // the same output as a fresh workspace.
        run_prop("workspace reuse", 29, 20, |rng| {
            let mut nets: Vec<MlpCore<f64>> = Vec::new();
            for _ in 0..3 {
                let dim = 1 + rng.below(4);
                let hidden = 1 + rng.below(12);
                let embed = 2 + rng.below(7); // odd embeds included
                let n_blocks = rng.below(3);
                nets.push(rand_net(rng, dim, hidden, embed, n_blocks));
            }
            for _ in 0..6 {
                let net = &nets[rng.below(nets.len())];
                let b = 1 + rng.below(24);
                let x = rng.normal_vec(b * net.dim);
                let uniform = rng.below(2) == 0;
                let t: Vec<f64> = if uniform {
                    vec![rng.uniform_in(0.01, 1.0); b]
                } else {
                    (0..b).map(|_| rng.uniform_in(0.01, 1.0)).collect()
                };
                let mut got = vec![0.0; b * net.dim];
                net.eval(&x, &t, b, &mut got);
                let want = if uniform {
                    // Fresh uniform-path reference (fresh ctx + workspace).
                    let mut uni = UniformScratch::default();
                    let ctx = net.build_uniform_ctx(t[0], &mut uni);
                    let mut out = vec![0.0; b * net.dim];
                    let mut scr = Scratch::default();
                    net.forward_rows(&x, None, b, &mut out, &mut scr, Some(ctx));
                    out
                } else {
                    fresh_forward(net, &x, &t, b)
                };
                assert_close(&got, &want, 1e-12, "workspace reuse parity");
            }
        });
    }

    /// Unit-level f32 parity: same weights at both precisions through the
    /// full f64-boundary eval (narrow → f32 engine → widen). Tolerance:
    /// see EXPERIMENTS.md §Kernels — f32 eps ~1.2e-7 per op, O(hidden)
    /// terms per matmul and a handful of layers keeps the relative error
    /// under ~1e-4 for O(1)-scale nets; 1e-3 leaves slack for unlucky
    /// cancellation.
    #[test]
    fn f32_engine_tracks_f64_within_tolerance() {
        let mut data_rng = Rng::new(170);
        for (i, (dim, hidden, embed, n_blocks, b)) in
            [(2, 16, 8, 2, 21), (3, 12, 6, 1, 8), (1, 4, 2, 0, 5)].into_iter().enumerate()
        {
            // Same seed twice → identical f64 weight draws, narrowed for
            // the f32 net exactly like weight loading does.
            let net64: MlpCore<f64> =
                rand_net(&mut Rng::new(17 + i as u64), dim, hidden, embed, n_blocks);
            let net32: MlpCore<f32> =
                rand_net(&mut Rng::new(17 + i as u64), dim, hidden, embed, n_blocks);
            let x = data_rng.normal_vec(b * dim);
            // Exercise both the uniform fast path and the generic path.
            for uniform in [true, false] {
                let t: Vec<f64> = if uniform {
                    vec![data_rng.uniform_in(0.01, 1.0); b]
                } else {
                    (0..b).map(|_| data_rng.uniform_in(0.01, 1.0)).collect()
                };
                let mut o64 = vec![0.0; b * dim];
                net64.eval(&x, &t, b, &mut o64);
                let mut o32 = vec![0.0; b * dim];
                net32.eval_widening(&x, &t, b, &mut o32);
                for (a, f) in o64.iter().zip(&o32) {
                    let tol = 1e-3 * (1.0 + a.abs());
                    assert!((a - f).abs() < tol, "f32 parity: {a} vs {f}");
                }
            }
        }
    }

    /// The wrapper reports what it was built as and routes eval correctly.
    #[test]
    fn wrapper_precision_and_dispatch() {
        let json = r#"{
          "dim": 1, "hidden": 2, "embed": 2, "n_blocks": 0,
          "params": {"w_in": [[1.0, -1.0]], "b_in": [0.0, 0.5],
                     "w_out": [[1.0], [2.0]], "b_out": [-0.25], "blocks": []}
        }"#;
        let root = Json::parse(json).unwrap();
        let net64 = NativeMlp::from_json_with(&root, Precision::F64).unwrap();
        let net32 = NativeMlp::from_json_with(&root, Precision::F32).unwrap();
        assert_eq!(net64.precision(), Precision::F64);
        assert_eq!(net32.precision(), Precision::F32);
        assert_eq!(net64.dim(), 1);
        assert_eq!(net32.dim(), 1);
        assert_eq!(net64.hidden(), 2);
        let (x, t) = ([0.75], [0.5]);
        let mut o64 = [0.0];
        let mut o32 = [0.0];
        net64.eval(&x, &t, 1, &mut o64);
        net32.eval(&x, &t, 1, &mut o32);
        assert!((o64[0] - o32[0]).abs() < 1e-3 * (1.0 + o64[0].abs()), "{o64:?} vs {o32:?}");
    }
}
