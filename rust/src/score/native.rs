//! Rust-native forward pass of the trained eps-net (weights_*.json).
//!
//! Mirrors python/compile/model.py::apply_eps exactly (same sinusoidal
//! embedding, same tanh-GELU). Used to (a) cross-check PJRT numerics against
//! an independent implementation (checks_*.json fixtures) and (b) drive the
//! big table sweeps without PJRT dispatch overhead.
//!
//! §Perf iteration 3 (EXPERIMENTS.md): the forward is now a zero-allocation
//! engine in the steady state.
//!
//!   * Batch chunks fan out over the persistent [`crate::score::pool`]
//!     worker pool instead of spawning a `thread::scope` thread set on
//!     every eval (i.e. on every solver step of every batch).
//!   * Every activation lives in a per-thread [`Scratch`] workspace reused
//!     across solver steps (the old code did ~6 `Mat::zeros` plus an
//!     `x.to_vec()` per chunk per eval).
//!   * Uniform-t fast path: solver stepping broadcasts a scalar t, so the
//!     time-embedding row and every per-block `e @ u` product are
//!     row-identical. They are computed once per eval into a
//!     [`UniformScratch`] and folded into each block's first bias, deleting
//!     one of the two matmuls per residual block; the GELU epilogue is
//!     fused into the remaining one (`matmul_rows::<false, true>`).
//!
//! `rust/tests/zero_alloc.rs` pins the no-steady-state-allocation claim
//! with a counting global allocator.

use std::cell::RefCell;

use anyhow::{Context, Result};

use crate::score::pool::WorkerPool;
use crate::score::EpsModel;
use crate::tensor::{gelu_slice, matmul_rows, Mat};
use crate::util::json::Json;

const TIME_SCALE: f64 = 1000.0; // keep in sync with kernels/ref.py

/// Flop threshold above which an eval fans out to the worker pool (below
/// it, dispatch overhead dominates the matmul work).
const PARALLEL_FLOPS: usize = 1 << 22;

struct Block {
    w1: Mat,
    b1: Vec<f64>,
    u: Mat,
    w2: Mat,
    b2: Vec<f64>,
}

pub struct NativeMlp {
    dim: usize,
    embed: usize,
    w_in: Mat,
    b_in: Vec<f64>,
    w_out: Mat,
    b_out: Vec<f64>,
    blocks: Vec<Block>,
    freqs: Vec<f64>,
    /// All-zero [hidden] bias for accumulate-only matmuls (generic-t path).
    zero_bias: Vec<f64>,
}

/// Per-thread activation arena. Buffers are length-adjusted in place (no
/// reallocation once capacity covers the working shape) and fully written
/// before they are read, so reuse across differing (b, dim) shapes can
/// never leak stale data — a property test below pins that.
#[derive(Default)]
struct Scratch {
    /// [b, hidden] residual stream.
    h: Vec<f64>,
    /// [b, hidden] block pre-activation.
    z: Vec<f64>,
    /// [b, embed] per-row time embedding (generic-t path only).
    e: Vec<f64>,
}

/// Per-eval uniform-t precompute: one embedding row and one combined
/// `b1 + e @ u` bias per block, shared read-only by every chunk task.
#[derive(Default)]
struct UniformScratch {
    e_row: Vec<f64>,
    /// [n_blocks, hidden], block-major.
    block_bias: Vec<f64>,
}

/// Borrowed view of the uniform-t precompute handed to chunk tasks.
#[derive(Clone, Copy)]
struct UniformCtx<'a> {
    /// [n_blocks, hidden] combined first-layer biases.
    block_bias: &'a [f64],
}

thread_local! {
    /// Chunk-forward workspace, owned by whichever thread runs the chunk
    /// (pool workers and dispatching callers alike).
    static SCRATCH: RefCell<Scratch> = RefCell::new(Scratch::default());
    /// Uniform-t precompute. Only the dispatching thread touches it; it is
    /// a separate thread-local from SCRATCH because the dispatcher holds
    /// the ctx borrow while itself executing chunk tasks (which need
    /// SCRATCH mutably).
    static UNIFORM: RefCell<UniformScratch> = RefCell::new(UniformScratch::default());
}

/// Adjust a workspace buffer's length, reusing capacity (new elements are
/// zeroed; retained elements keep whatever the previous use left — callers
/// fully overwrite before reading).
#[inline]
fn set_len(buf: &mut Vec<f64>, len: usize) {
    buf.resize(len, 0.0);
}

/// `*mut f64` wrapper so chunk tasks can carve disjoint output windows
/// through a shared `Fn` closure.
#[derive(Clone, Copy)]
struct SendPtr(*mut f64);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

impl NativeMlp {
    pub fn load(path: &str) -> Result<NativeMlp> {
        let root = Json::from_file(path)?;
        Self::from_json(&root).with_context(|| format!("weights file {path}"))
    }

    pub fn from_json(root: &Json) -> Result<NativeMlp> {
        let dim = root.get("dim")?.as_usize()?;
        let embed = root.get("embed")?.as_usize()?;
        let p = root.get("params")?;
        let mat = |v: &Json| -> Result<Mat> {
            let (r, c, data) = v.as_matrix()?;
            Ok(Mat::from_rows(r, c, data))
        };
        let mut blocks = Vec::new();
        for blk in p.get("blocks")?.as_arr()? {
            blocks.push(Block {
                w1: mat(blk.get("w1")?)?,
                b1: blk.get("b1")?.as_f64_vec()?,
                u: mat(blk.get("u")?)?,
                w2: mat(blk.get("w2")?)?,
                b2: blk.get("b2")?.as_f64_vec()?,
            });
        }
        let half = embed / 2;
        let freqs = (0..half)
            .map(|i| (-(10000.0f64).ln() * i as f64 / half as f64).exp())
            .collect();
        let w_in = mat(p.get("w_in")?)?;
        let zero_bias = vec![0.0; w_in.cols];
        Ok(NativeMlp {
            dim,
            embed,
            w_in,
            b_in: p.get("b_in")?.as_f64_vec()?,
            w_out: mat(p.get("w_out")?)?,
            b_out: p.get("b_out")?.as_f64_vec()?,
            blocks,
            freqs,
            zero_bias,
        })
    }

    pub fn hidden(&self) -> usize {
        self.w_in.cols
    }

    /// Sinusoidal embedding of one scalar t into `row` ([embed]).
    fn time_embed_row(&self, t: f64, row: &mut [f64]) {
        let half = self.embed / 2;
        for (i, &f) in self.freqs.iter().enumerate() {
            let ang = TIME_SCALE * t * f;
            row[i] = ang.sin();
            row[half + i] = ang.cos();
        }
    }

    /// Uniform-t precompute: embedding row once, then fold `e @ u` into each
    /// block's first-layer bias (`bias_j = b1_j + e_row @ u_j`).
    fn build_uniform_ctx<'a>(&self, t: f64, uni: &'a mut UniformScratch) -> UniformCtx<'a> {
        set_len(&mut uni.e_row, self.embed);
        if self.embed % 2 == 1 {
            // Odd embed: the element past the sin/cos halves is never
            // written by time_embed_row.
            uni.e_row.fill(0.0);
        }
        self.time_embed_row(t, &mut uni.e_row);
        let hd = self.hidden();
        set_len(&mut uni.block_bias, self.blocks.len() * hd);
        uni.block_bias.fill(0.0); // ACC kernel accumulates on top
        let UniformScratch { e_row, block_bias } = uni;
        for (j, blk) in self.blocks.iter().enumerate() {
            matmul_rows::<true, false>(
                &e_row[..],
                self.embed,
                &blk.u,
                &blk.b1,
                &mut block_bias[j * hd..(j + 1) * hd],
            );
        }
        UniformCtx { block_bias: &block_bias[..] }
    }

    /// Forward for `b` contiguous rows on the current thread. With a
    /// uniform-t `ctx` the per-block update is two fused matmuls
    /// (`gelu(h @ w1 + bias_j)` and `h += z @ w2 + b2`); without it, the
    /// per-row embedding and `e @ u` matmul run as in the generic math.
    fn forward_rows(
        &self,
        x: &[f64],
        t: Option<&[f64]>,
        b: usize,
        out: &mut [f64],
        scr: &mut Scratch,
        ctx: Option<UniformCtx<'_>>,
    ) {
        let hd = self.hidden();
        set_len(&mut scr.h, b * hd);
        matmul_rows::<false, false>(x, self.dim, &self.w_in, &self.b_in, &mut scr.h);
        set_len(&mut scr.z, b * hd);
        match ctx {
            Some(c) => {
                for (j, blk) in self.blocks.iter().enumerate() {
                    let bias = &c.block_bias[j * hd..(j + 1) * hd];
                    // z = gelu(h @ w1 + (b1 + e @ u)), GELU in the epilogue.
                    matmul_rows::<false, true>(&scr.h, hd, &blk.w1, bias, &mut scr.z);
                    // h += z @ w2 + b2, residual add in the epilogue.
                    matmul_rows::<true, false>(&scr.z, hd, &blk.w2, &blk.b2, &mut scr.h);
                }
            }
            None => {
                let t = t.expect("generic path needs per-row t");
                set_len(&mut scr.e, b * self.embed);
                if self.embed % 2 == 1 {
                    scr.e.fill(0.0);
                }
                for (r, &tv) in t.iter().enumerate() {
                    self.time_embed_row(tv, &mut scr.e[r * self.embed..(r + 1) * self.embed]);
                }
                for blk in &self.blocks {
                    // z = h @ w1 + b1 + e @ u, then GELU.
                    matmul_rows::<false, false>(&scr.h, hd, &blk.w1, &blk.b1, &mut scr.z);
                    matmul_rows::<true, false>(
                        &scr.e,
                        self.embed,
                        &blk.u,
                        &self.zero_bias,
                        &mut scr.z,
                    );
                    gelu_slice(&mut scr.z);
                    matmul_rows::<true, false>(&scr.z, hd, &blk.w2, &blk.b2, &mut scr.h);
                }
            }
        }
        matmul_rows::<false, false>(&scr.h, hd, &self.w_out, &self.b_out, out);
    }

    /// Split the batch into `n_chunks` row ranges and run them across the
    /// pool (the calling thread participates; with `n_chunks == 1` it runs
    /// the whole batch inline).
    fn run_chunks(
        &self,
        x: &[f64],
        t: Option<&[f64]>,
        b: usize,
        out: &mut [f64],
        n_chunks: usize,
        ctx: Option<UniformCtx<'_>>,
        pool: &WorkerPool,
    ) {
        let d = self.dim;
        if n_chunks <= 1 {
            SCRATCH.with(|s| {
                let scr = &mut *s.borrow_mut();
                self.forward_rows(x, t, b, out, scr, ctx);
            });
            return;
        }
        let chunk_rows = b.div_ceil(n_chunks);
        let nc = b.div_ceil(chunk_rows);
        let optr = SendPtr(out.as_mut_ptr());
        let task = move |ci: usize| {
            let row0 = ci * chunk_rows;
            let rows = chunk_rows.min(b - row0);
            // Disjoint window: chunk ci owns rows [row0, row0 + rows).
            let o = unsafe { std::slice::from_raw_parts_mut(optr.0.add(row0 * d), rows * d) };
            let xs = &x[row0 * d..(row0 + rows) * d];
            let ts = t.map(|tt| &tt[row0..row0 + rows]);
            SCRATCH.with(|s| {
                let scr = &mut *s.borrow_mut();
                self.forward_rows(xs, ts, rows, o, scr, ctx);
            });
        };
        pool.run(nc, &task);
    }
}

impl EpsModel for NativeMlp {
    fn dim(&self) -> usize {
        self.dim
    }

    fn eval(&self, x: &[f64], t: &[f64], b: usize, out: &mut [f64]) {
        let d = self.dim;
        assert_eq!(x.len(), b * d);
        assert_eq!(t.len(), b);
        assert_eq!(out.len(), b * d);
        if b == 0 {
            return;
        }
        let pool = WorkerPool::global();
        let flops = 2 * b * self.hidden() * self.hidden() * (2 * self.blocks.len() + 1);
        let par = if flops > PARALLEL_FLOPS { pool.threads() } else { 1 };
        let n_chunks = if par <= 1 || b < 2 * par { 1 } else { par };
        // Solver stepping broadcasts a scalar t; detect it and take the
        // shared-embedding fast path.
        if t.iter().all(|&tv| tv == t[0]) {
            UNIFORM.with(|u| {
                let uni = &mut *u.borrow_mut();
                let ctx = self.build_uniform_ctx(t[0], uni);
                self.run_chunks(x, None, b, out, n_chunks, Some(ctx), pool);
            });
        } else {
            self.run_chunks(x, Some(t), b, out, n_chunks, None, pool);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_close, run_prop};
    use crate::util::rng::Rng;

    /// Hand-built one-block net with identity-ish weights; oracle computed
    /// by transcribing the python math by hand.
    #[test]
    fn forward_matches_hand_computation() {
        let json = r#"{
          "dim": 1, "hidden": 2, "embed": 2, "n_blocks": 1,
          "params": {
            "w_in": [[1.0, 2.0]], "b_in": [0.1, -0.1],
            "w_out": [[1.0], [1.0]], "b_out": [0.5],
            "blocks": [{
              "w1": [[1.0, 0.0], [0.0, 1.0]], "b1": [0.0, 0.0],
              "u":  [[0.0, 0.0], [0.0, 0.0]],
              "w2": [[1.0, 0.0], [0.0, 1.0]], "b2": [0.0, 0.0]
            }]
          }
        }"#;
        let net = NativeMlp::from_json(&Json::parse(json).unwrap()).unwrap();
        let x = [2.0];
        let t = [0.0];
        let mut out = [0.0];
        net.eval(&x, &t, 1, &mut out);
        // h = [2.1, 3.9]; block: h + gelu(h) = [2.1+gelu(2.1), 3.9+gelu(3.9)]
        let g = |v: f64| crate::tensor::gelu(v);
        let want = (2.1 + g(2.1)) + (3.9 + g(3.9)) + 0.5;
        assert!((out[0] - want).abs() < 1e-12, "{} vs {}", out[0], want);
    }

    #[test]
    fn time_embed_matches_formula() {
        let json = r#"{
          "dim": 1, "hidden": 1, "embed": 4, "n_blocks": 0,
          "params": {"w_in": [[1.0]], "b_in": [0.0], "w_out": [[1.0]],
                     "b_out": [0.0], "blocks": []}
        }"#;
        let net = NativeMlp::from_json(&Json::parse(json).unwrap()).unwrap();
        let mut e = [0.0; 4];
        net.time_embed_row(0.001, &mut e);
        // freqs = [1, exp(-ln(1e4)/2)] = [1, 0.01]; ang = [1.0, 0.01]
        assert!((e[0] - 1.0f64.sin()).abs() < 1e-12);
        assert!((e[1] - 0.01f64.sin()).abs() < 1e-12);
        assert!((e[2] - 1.0f64.cos()).abs() < 1e-12);
        assert!((e[3] - 0.01f64.cos()).abs() < 1e-12);
    }

    fn rand_block(rng: &mut Rng, hidden: usize, embed: usize) -> Block {
        Block {
            w1: Mat::from_rows(hidden, hidden, rng.normal_vec(hidden * hidden)),
            b1: rng.normal_vec(hidden),
            u: Mat::from_rows(embed, hidden, rng.normal_vec(embed * hidden)),
            w2: Mat::from_rows(hidden, hidden, rng.normal_vec(hidden * hidden)),
            b2: rng.normal_vec(hidden),
        }
    }

    fn rand_net(rng: &mut Rng, dim: usize, hidden: usize, embed: usize, n_blocks: usize)
        -> NativeMlp {
        let half = embed / 2;
        NativeMlp {
            dim,
            embed,
            w_in: Mat::from_rows(dim, hidden, rng.normal_vec(dim * hidden)),
            b_in: rng.normal_vec(hidden),
            w_out: Mat::from_rows(hidden, dim, rng.normal_vec(hidden * dim)),
            b_out: rng.normal_vec(dim),
            blocks: (0..n_blocks).map(|_| rand_block(rng, hidden, embed)).collect(),
            freqs: (0..half)
                .map(|i| (-(10000.0f64).ln() * i as f64 / half as f64).exp())
                .collect(),
            zero_bias: vec![0.0; hidden],
        }
    }

    /// Reference forward with a brand-new workspace (no shared state).
    fn fresh_forward(net: &NativeMlp, x: &[f64], t: &[f64], b: usize) -> Vec<f64> {
        let mut out = vec![0.0; b * net.dim];
        let mut scr = Scratch::default();
        net.forward_rows(x, Some(t), b, &mut out, &mut scr, None);
        out
    }

    #[test]
    fn pooled_matches_single_thread() {
        let mut rng = Rng::new(11);
        let net = rand_net(&mut rng, 3, 9, 6, 2);
        let b = 37; // odd: exercises the tail-row kernel and ragged chunks
        let x = rng.normal_vec(b * 3);
        let t: Vec<f64> = (0..b).map(|_| rng.uniform_in(0.01, 1.0)).collect();
        let pool = WorkerPool::global();
        let mut single = vec![0.0; b * 3];
        net.run_chunks(&x, Some(&t), b, &mut single, 1, None, pool);
        for n_chunks in [2, 3, 4, 7] {
            let mut pooled = vec![0.0; b * 3];
            net.run_chunks(&x, Some(&t), b, &mut pooled, n_chunks, None, pool);
            assert_close(&pooled, &single, 1e-12, "pooled vs single-thread forward");
        }
    }

    #[test]
    fn uniform_fast_path_matches_generic() {
        let mut rng = Rng::new(13);
        for (dim, hidden, embed, n_blocks) in [(2, 8, 4, 1), (3, 7, 5, 3), (1, 4, 2, 0)] {
            let net = rand_net(&mut rng, dim, hidden, embed, n_blocks);
            let b = 19;
            let x = rng.normal_vec(b * dim);
            let tv = rng.uniform_in(0.01, 1.0);
            let t = vec![tv; b];
            // eval() auto-detects the uniform t and takes the fast path.
            let mut fast = vec![0.0; b * dim];
            net.eval(&x, &t, b, &mut fast);
            let generic = fresh_forward(&net, &x, &t, b);
            assert_close(&fast, &generic, 1e-12, "uniform fast path vs generic");
        }
    }

    #[test]
    fn workspace_reuse_across_shapes_never_aliases_stale_data() {
        // Interleave evals of different (b, dim, hidden, embed) shapes on
        // one thread; the shared thread-local workspace must always produce
        // the same output as a fresh workspace.
        run_prop("workspace reuse", 29, 20, |rng| {
            let mut nets = Vec::new();
            for _ in 0..3 {
                let dim = 1 + rng.below(4);
                let hidden = 1 + rng.below(12);
                let embed = 2 + rng.below(7); // odd embeds included
                let n_blocks = rng.below(3);
                nets.push(rand_net(rng, dim, hidden, embed, n_blocks));
            }
            for _ in 0..6 {
                let net = &nets[rng.below(nets.len())];
                let b = 1 + rng.below(24);
                let x = rng.normal_vec(b * net.dim);
                let uniform = rng.below(2) == 0;
                let t: Vec<f64> = if uniform {
                    vec![rng.uniform_in(0.01, 1.0); b]
                } else {
                    (0..b).map(|_| rng.uniform_in(0.01, 1.0)).collect()
                };
                let mut got = vec![0.0; b * net.dim];
                net.eval(&x, &t, b, &mut got);
                let want = if uniform {
                    // Fresh uniform-path reference (fresh ctx + workspace).
                    let mut uni = UniformScratch::default();
                    let ctx = net.build_uniform_ctx(t[0], &mut uni);
                    let mut out = vec![0.0; b * net.dim];
                    let mut scr = Scratch::default();
                    net.forward_rows(&x, None, b, &mut out, &mut scr, Some(ctx));
                    out
                } else {
                    fresh_forward(net, &x, &t, b)
                };
                assert_close(&got, &want, 1e-12, "workspace reuse parity");
            }
        });
    }
}
