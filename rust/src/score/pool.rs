//! Persistent worker pool for the native inference engine.
//!
//! `NativeMlp::eval` used to `std::thread::scope` + spawn a fresh set of OS
//! threads on every call — i.e. on every solver step of every batch, which
//! is exactly the per-step cost DEIS says should be all network math. This
//! pool is created once (lazily, like `Runtime::global()`) and fans fixed
//! index ranges out to long-lived threads with nothing but a mutex hand-off
//! and two condvar signals per job: no spawn, no join, and — deliberately —
//! no channel sends, because `std::sync::mpsc` heap-allocates a node per
//! message and the engine's contract is zero steady-state allocation
//! (verified by `rust/tests/zero_alloc.rs`).
//!
//! Design notes:
//!   * One job at a time (`run_lock`); concurrent callers serialize. That is
//!     the right trade here: a job already spans every worker, so a second
//!     concurrent job could only time-slice the same cores.
//!   * The job lives on the caller's stack. Workers receive a raw pointer
//!     through the mutex-protected slot; the caller cannot return (or unwind)
//!     before every worker has checked back in, so the pointer never
//!     outlives the job (see `run` for the unwind guard).
//!   * Work stealing is unnecessary: tasks are claimed one index at a time
//!     from a shared atomic counter, which is already perfectly balanced for
//!     the homogeneous row-chunk tasks the engine submits.

use std::cell::Cell;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

thread_local! {
    /// True while this thread is executing a pool task. A nested `run`
    /// would deadlock on `run_lock` (the outer job holds it until every
    /// worker checks in), so re-entrant calls degrade to inline execution.
    static IN_TASK: Cell<bool> = const { Cell::new(false) };
}

/// A dispatched job: lifetime-erased task closure + claim counter.
struct Job {
    /// The task, `fn(index)`. Only dereferenced for successfully claimed
    /// indices, all of which complete before `run` returns.
    task: *const (dyn Fn(usize) + Sync),
    next: AtomicUsize,
    total: usize,
    panicked: AtomicBool,
}

/// Mutex-protected dispatch slot shared by all workers.
struct Slot {
    /// Bumped once per job; workers wait for it to move past their last seen
    /// value, so every worker joins every job exactly once.
    seq: u64,
    job: *const Job,
    /// Workers that have not yet finished the current job.
    remaining: usize,
    shutdown: bool,
}

// The raw pointers are only dereferenced between dispatch and completion,
// both of which happen inside `run`'s critical section (see module doc).
unsafe impl Send for Slot {}

struct Shared {
    slot: Mutex<Slot>,
    cv_workers: Condvar,
    cv_done: Condvar,
}

/// Persistent thread pool; `global()` is the process-wide instance the
/// native engine uses.
pub struct WorkerPool {
    shared: Arc<Shared>,
    /// Serializes jobs (one active job at a time).
    run_lock: Mutex<()>,
    workers: usize,
}

static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();

impl WorkerPool {
    /// Pool with `workers` extra threads (the calling thread always
    /// participates, so total parallelism is `workers + 1`).
    pub fn new(workers: usize) -> WorkerPool {
        let shared = Arc::new(Shared {
            slot: Mutex::new(Slot { seq: 0, job: std::ptr::null(), remaining: 0, shutdown: false }),
            cv_workers: Condvar::new(),
            cv_done: Condvar::new(),
        });
        let mut spawned = 0;
        for i in 0..workers {
            let sh = shared.clone();
            let ok = std::thread::Builder::new()
                .name(format!("deis-pool-{i}"))
                .spawn(move || worker_loop(&sh))
                .is_ok();
            // Count only live workers: `run` waits for exactly this many
            // check-ins per job, so a failed spawn must not be counted.
            if ok {
                spawned += 1;
            }
        }
        WorkerPool { shared, run_lock: Mutex::new(()), workers: spawned }
    }

    /// Process-wide pool sized to the machine (capped at 8, matching the old
    /// per-eval spawn cap; override with `DEIS_POOL_THREADS` = total
    /// parallelism including the caller).
    pub fn global() -> &'static WorkerPool {
        GLOBAL.get_or_init(|| {
            let par = std::env::var("DEIS_POOL_THREADS")
                .ok()
                .and_then(|v| v.parse::<usize>().ok())
                .unwrap_or_else(|| {
                    std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1).min(8)
                })
                .max(1);
            WorkerPool::new(par - 1)
        })
    }

    /// Total parallelism of a `run` call (workers + the caller).
    pub fn threads(&self) -> usize {
        self.workers + 1
    }

    /// Execute `task(i)` for every `i in 0..total`, fanning indices across
    /// the pool. Blocks until all indices are done. Panics in any task are
    /// re-raised here after the job fully drains (so the pool stays usable).
    pub fn run(&self, total: usize, task: &(dyn Fn(usize) + Sync)) {
        if self.workers == 0 || total <= 1 || IN_TASK.with(|t| t.get()) {
            for i in 0..total {
                task(i);
            }
            return;
        }
        let guard = self.run_lock.lock().unwrap();
        // Erase the task's borrow lifetime so it can sit in the (plain-type)
        // job slot; sound because `run` does not return (or unwind) until
        // every participant has finished with it.
        let task_erased: &'static (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(task)
        };
        let job = Job {
            task: task_erased as *const (dyn Fn(usize) + Sync),
            next: AtomicUsize::new(0),
            total,
            panicked: AtomicBool::new(false),
        };
        {
            let mut slot = self.shared.slot.lock().unwrap();
            slot.seq = slot.seq.wrapping_add(1);
            slot.job = &job as *const Job;
            slot.remaining = self.workers;
            self.shared.cv_workers.notify_all();
        }
        // The caller participates too; catch panics so we never unwind past
        // the worker check-in barrier while they still hold `&job`.
        let caller_result = std::panic::catch_unwind(AssertUnwindSafe(|| loop {
            let i = job.next.fetch_add(1, Ordering::Relaxed);
            if i >= job.total {
                break;
            }
            IN_TASK.with(|t| t.set(true));
            let r = std::panic::catch_unwind(AssertUnwindSafe(|| task(i)));
            IN_TASK.with(|t| t.set(false));
            if let Err(p) = r {
                std::panic::resume_unwind(p);
            }
        }));
        {
            let mut slot = self.shared.slot.lock().unwrap();
            while slot.remaining > 0 {
                slot = self.shared.cv_done.wait(slot).unwrap();
            }
            slot.job = std::ptr::null();
        }
        drop(guard);
        if let Err(p) = caller_result {
            std::panic::resume_unwind(p);
        }
        if job.panicked.load(Ordering::Relaxed) {
            panic!("worker pool task panicked");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        let mut slot = self.shared.slot.lock().unwrap();
        slot.shutdown = true;
        slot.seq = slot.seq.wrapping_add(1);
        slot.job = std::ptr::null();
        self.shared.cv_workers.notify_all();
    }
}

fn worker_loop(sh: &Shared) {
    let mut last_seq = 0u64;
    loop {
        let job_ptr = {
            let mut slot = sh.slot.lock().unwrap();
            while slot.seq == last_seq {
                slot = sh.cv_workers.wait(slot).unwrap();
            }
            last_seq = slot.seq;
            if slot.shutdown {
                return;
            }
            slot.job
        };
        // Safe: the dispatching `run` call blocks until this worker checks
        // back in below, so `job` (on that caller's stack) is alive.
        let job = unsafe { &*job_ptr };
        let task = unsafe { &*job.task };
        loop {
            let i = job.next.fetch_add(1, Ordering::Relaxed);
            if i >= job.total {
                break;
            }
            IN_TASK.with(|t| t.set(true));
            let r = std::panic::catch_unwind(AssertUnwindSafe(|| task(i)));
            IN_TASK.with(|t| t.set(false));
            if r.is_err() {
                job.panicked.store(true, Ordering::Relaxed);
            }
        }
        let mut slot = sh.slot.lock().unwrap();
        slot.remaining -= 1;
        if slot.remaining == 0 {
            sh.cv_done.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_every_index_exactly_once() {
        let pool = WorkerPool::new(3);
        for total in [0, 1, 2, 7, 64, 1000] {
            let hits: Vec<AtomicU64> = (0..total).map(|_| AtomicU64::new(0)).collect();
            pool.run(total, &|i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "index {i} of {total}");
            }
        }
    }

    #[test]
    fn zero_worker_pool_runs_inline() {
        let pool = WorkerPool::new(0);
        let count = AtomicUsize::new(0);
        pool.run(17, &|_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 17);
    }

    #[test]
    fn sequential_jobs_reuse_workers() {
        let pool = WorkerPool::new(2);
        for round in 0..50 {
            let sum = AtomicUsize::new(0);
            pool.run(10, &|i| {
                sum.fetch_add(i + 1, Ordering::Relaxed);
            });
            assert_eq!(sum.load(Ordering::Relaxed), 55, "round {round}");
        }
    }

    #[test]
    fn concurrent_callers_serialize_correctly() {
        let pool = std::sync::Arc::new(WorkerPool::new(2));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let p = pool.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..25 {
                    let sum = AtomicUsize::new(0);
                    p.run(8, &|i| {
                        sum.fetch_add(i, Ordering::Relaxed);
                    });
                    assert_eq!(sum.load(Ordering::Relaxed), 28);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn task_panic_propagates_but_pool_survives() {
        let pool = WorkerPool::new(1);
        let res = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(4, &|i| {
                if i == 2 {
                    panic!("boom");
                }
            });
        }));
        assert!(res.is_err());
        // Pool still functional afterwards.
        let count = AtomicUsize::new(0);
        pool.run(4, &|_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn nested_run_degrades_to_inline() {
        let pool = WorkerPool::new(2);
        let count = AtomicUsize::new(0);
        pool.run(4, &|_| {
            pool.run(3, &|_| {
                count.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(count.load(Ordering::Relaxed), 12);
    }

    #[test]
    fn global_pool_is_shared() {
        let a = WorkerPool::global() as *const _;
        let b = WorkerPool::global() as *const _;
        assert_eq!(a, b);
        assert!(WorkerPool::global().threads() >= 1);
    }
}
