//! Deterministic fault injection for the serving stack.
//!
//! [`FaultyEps`] wraps any [`EpsModel`] and misbehaves on *scripted* eval
//! indices only: eval #k can stall for a fixed duration, panic, return
//! non-finite values, or any combination (applied in that order, so a
//! "stall then panic" eval deterministically overlaps a request deadline
//! before it blows up). Everything off-script passes through bit-exactly,
//! which is what lets the chaos battery assert bit-exact parity for
//! requests that dodge the faults.
//!
//! The eval counter is the wrapper's own dispatch count (one merged
//! scheduler eval = one tick), so a plan is deterministic as long as the
//! test serializes the evals it wants to hit — e.g. one worker, or one
//! request at a time.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

use super::EpsModel;

/// What one scripted eval does, beyond (or instead of) real model math.
/// Fields compose: `stall_ms` sleeps first, then `panic` unwinds, then the
/// inner model runs and `nan` overwrites its output.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Fault {
    /// Sleep this long before doing anything else.
    pub stall_ms: u64,
    /// Panic (after any stall) instead of evaluating.
    pub panic: bool,
    /// Overwrite the output with NaNs after evaluating.
    pub nan: bool,
}

impl Fault {
    pub fn is_noop(&self) -> bool {
        *self == Fault::default()
    }
}

/// A script of `(eval index, fault)` entries. Builder-style:
///
/// ```ignore
/// let plan = FaultPlan::new().panic_on(0).panic_on(1).stall_on(2, 150).nan_on(3);
/// ```
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    faults: Vec<(usize, Fault)>,
}

impl FaultPlan {
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    fn entry(&mut self, eval: usize) -> &mut Fault {
        if let Some(pos) = self.faults.iter().position(|(e, _)| *e == eval) {
            return &mut self.faults[pos].1;
        }
        self.faults.push((eval, Fault::default()));
        &mut self.faults.last_mut().unwrap().1
    }

    /// Panic on eval #`eval`.
    pub fn panic_on(mut self, eval: usize) -> FaultPlan {
        self.entry(eval).panic = true;
        self
    }

    /// Stall eval #`eval` for `ms` milliseconds (then run it normally,
    /// unless another fault is also scripted for the same index).
    pub fn stall_on(mut self, eval: usize, ms: u64) -> FaultPlan {
        self.entry(eval).stall_ms = ms;
        self
    }

    /// Return all-NaN output from eval #`eval`.
    pub fn nan_on(mut self, eval: usize) -> FaultPlan {
        self.entry(eval).nan = true;
        self
    }

    /// The scripted fault for eval #`eval` (no-op if unscripted).
    pub fn fault_for(&self, eval: usize) -> Fault {
        self.faults
            .iter()
            .find(|(e, _)| *e == eval)
            .map(|(_, f)| *f)
            .unwrap_or_default()
    }
}

/// An [`EpsModel`] that follows a [`FaultPlan`]. Off-script evals are
/// bit-exact pass-throughs to the inner model.
pub struct FaultyEps<M> {
    inner: M,
    plan: FaultPlan,
    evals: AtomicUsize,
}

impl<M: EpsModel> FaultyEps<M> {
    pub fn new(inner: M, plan: FaultPlan) -> FaultyEps<M> {
        FaultyEps { inner, plan, evals: AtomicUsize::new(0) }
    }

    /// Evals dispatched so far (including panicked ones).
    pub fn evals(&self) -> usize {
        self.evals.load(Ordering::SeqCst)
    }
}

impl<M: EpsModel> EpsModel for FaultyEps<M> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn eval(&self, x: &[f64], t: &[f64], b: usize, out: &mut [f64]) {
        let idx = self.evals.fetch_add(1, Ordering::SeqCst);
        let fault = self.plan.fault_for(idx);
        if fault.stall_ms > 0 {
            std::thread::sleep(Duration::from_millis(fault.stall_ms));
        }
        if fault.panic {
            panic!("injected fault: ε-eval #{idx} panicked (FaultPlan)");
        }
        self.inner.eval(x, t, b, out);
        if fault.nan {
            for v in out[..b * self.inner.dim()].iter_mut() {
                *v = f64::NAN;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::diffusion::Sde;
    use crate::gmm::Gmm;
    use crate::score::GmmEps;
    use std::panic::AssertUnwindSafe;

    fn oracle() -> GmmEps {
        GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp())
    }

    #[test]
    fn off_script_evals_are_bit_exact() {
        let plain = oracle();
        let faulty = FaultyEps::new(oracle(), FaultPlan::new().panic_on(99));
        let x = vec![0.5, -0.5, 1.0, 2.0];
        let t = vec![0.5, 0.5];
        assert_eq!(faulty.eval_vec(&x, &t, 2), plain.eval_vec(&x, &t, 2));
        assert_eq!(faulty.evals(), 1);
    }

    #[test]
    fn scripted_panic_fires_on_exact_index() {
        let faulty = FaultyEps::new(oracle(), FaultPlan::new().panic_on(1));
        let x = vec![1.0, 0.0];
        let t = vec![0.3];
        let mut out = vec![0.0; 2];
        faulty.eval(&x, &t, 1, &mut out); // eval #0: fine
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let mut out = vec![0.0; 2];
            faulty.eval(&x, &t, 1, &mut out); // eval #1: scripted panic
        }));
        assert!(r.is_err(), "eval #1 must panic");
        faulty.eval(&x, &t, 1, &mut out); // eval #2: recovered
        assert_eq!(faulty.evals(), 3);
    }

    #[test]
    fn nan_mode_poisons_every_output_value() {
        let faulty = FaultyEps::new(oracle(), FaultPlan::new().nan_on(0));
        let x = vec![0.5, -0.5, 1.0, 2.0];
        let t = vec![0.5, 0.5];
        let mut out = vec![0.0; 4];
        faulty.eval(&x, &t, 2, &mut out);
        assert!(out.iter().all(|v| v.is_nan()), "{out:?}");
    }

    #[test]
    fn stall_composes_with_panic_and_plan_merges_entries() {
        let plan = FaultPlan::new().stall_on(4, 120).panic_on(4);
        let f = plan.fault_for(4);
        assert_eq!(f, Fault { stall_ms: 120, panic: true, nan: false });
        assert!(plan.fault_for(3).is_noop());

        let faulty = FaultyEps::new(oracle(), FaultPlan::new().stall_on(0, 30));
        let x = vec![1.0, 0.0];
        let t = vec![0.3];
        let mut out = vec![0.0; 2];
        let t0 = std::time::Instant::now();
        faulty.eval(&x, &t, 1, &mut out);
        assert!(t0.elapsed() >= Duration::from_millis(25), "stall did not bite");
        assert!(out.iter().all(|v| v.is_finite()));
    }
}
