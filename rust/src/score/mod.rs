//! The eps-model abstraction (the paper's ε_θ) and its backends.
//!
//! Solvers are written against `EpsModel` only; the same tAB-DEIS plan runs
//! against the PJRT-compiled network (serving), the rust-native MLP
//! (sweeps + cross-check), or the analytic GMM oracle (exact-score studies).

pub mod faulty;
mod native;
pub mod pjrt;
pub mod pool;

pub use faulty::{Fault, FaultPlan, FaultyEps};
pub use native::{NativeMlp, Precision};

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::diffusion::Sde;
use crate::gmm::Gmm;

/// Batched ε_θ(x, t). `x` is row-major [b * dim], `t` is [b]; result is
/// written into `out` ([b * dim]).
pub trait EpsModel: Send + Sync {
    fn dim(&self) -> usize;
    fn eval(&self, x: &[f64], t: &[f64], b: usize, out: &mut [f64]);

    /// Convenience allocating wrapper.
    fn eval_vec(&self, x: &[f64], t: &[f64], b: usize) -> Vec<f64> {
        let mut out = vec![0.0; b * self.dim()];
        self.eval(x, t, b, &mut out);
        out
    }
}

/// Exact GMM oracle as an `EpsModel` (fixed SDE baked in).
pub struct GmmEps {
    pub gmm: Gmm,
    pub sde: Sde,
}

impl GmmEps {
    pub fn new(gmm: Gmm, sde: Sde) -> Self {
        GmmEps { gmm, sde }
    }
}

impl EpsModel for GmmEps {
    fn dim(&self) -> usize {
        self.gmm.dim()
    }

    fn eval(&self, x: &[f64], t: &[f64], b: usize, out: &mut [f64]) {
        self.gmm.eps(&self.sde, x, t, b, out);
    }
}

/// NFE-counting wrapper — every table in the paper is indexed by NFE, so the
/// harness wraps models with this and asserts the budget was respected.
pub struct Counting<'a> {
    pub inner: &'a dyn EpsModel,
    count: AtomicUsize,
}

impl<'a> Counting<'a> {
    pub fn new(inner: &'a dyn EpsModel) -> Self {
        Counting { inner, count: AtomicUsize::new(0) }
    }

    /// Number of *model calls* so far (one batched eval = 1 NFE, matching the
    /// paper's counting: NFE is per-trajectory network evaluations).
    pub fn nfe(&self) -> usize {
        self.count.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.count.store(0, Ordering::Relaxed);
    }
}

impl EpsModel for Counting<'_> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn eval(&self, x: &[f64], t: &[f64], b: usize, out: &mut [f64]) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.inner.eval(x, t, b, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counting_counts() {
        let gmm = Gmm::ring2d(4.0, 8, 0.25);
        let model = GmmEps::new(gmm, Sde::vp());
        let counted = Counting::new(&model);
        let x = vec![0.5, -0.5, 1.0, 2.0];
        let t = vec![0.5, 0.5];
        let mut out = vec![0.0; 4];
        counted.eval(&x, &t, 2, &mut out);
        counted.eval(&x, &t, 2, &mut out);
        assert_eq!(counted.nfe(), 2);
        counted.reset();
        assert_eq!(counted.nfe(), 0);
    }

    #[test]
    fn gmm_eps_model_delegates() {
        let gmm = Gmm::ring2d(4.0, 8, 0.25);
        let sde = Sde::vp();
        let model = GmmEps::new(gmm.clone(), sde);
        let x = vec![1.0, 0.0];
        let t = vec![0.3];
        let got = model.eval_vec(&x, &t, 1);
        let mut want = vec![0.0; 2];
        gmm.eps(&sde, &x, &t, 1, &mut want);
        assert_eq!(got, want);
    }
}
