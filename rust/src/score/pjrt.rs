//! PJRT-backed eps model: the serving hot path.
//!
//! Wraps one or more compiled (batch-size) entry points of a model and
//! routes an arbitrary logical batch to the smallest fitting artifact,
//! chunking and padding as needed (padding rows reuse the first row of the
//! chunk; their outputs are discarded).

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::runtime::{pick_batch, EpsExecutable, Runtime};
use crate::score::EpsModel;
use crate::util::json::Json;

pub struct PjrtEps {
    pub model: String,
    dim: usize,
    exes: Vec<Arc<EpsExecutable>>, // sorted by batch ascending
}

impl PjrtEps {
    /// Load model `name` (e.g. "gmm2d", "gmm2d_xla", "gmm2d_exact") with the
    /// batch sizes recorded in artifacts/meta.json (falls back to `batches`).
    pub fn load(rt: &Runtime, name: &str, batches: &[usize]) -> Result<PjrtEps> {
        let meta = Json::from_file(&rt.artifacts_dir().join("meta.json").to_string_lossy())?;
        // "gmm2d_xla" / "gmm2d_exact" reuse the base model's dim.
        let base = name.split('_').next().unwrap_or(name);
        let dim = match meta.get("models").and_then(|m| m.get(base)) {
            Ok(info) => info.get("dim")?.as_usize()?,
            Err(_) => 2, // analytic artifacts are 2-d
        };
        let mut exes = Vec::new();
        let mut bs: Vec<usize> = batches.to_vec();
        bs.sort_unstable();
        for b in bs {
            let file = format!("eps_{name}_b{b}.hlo.txt");
            let exe = rt
                .load_eps(&file, b, dim, 1)
                .with_context(|| format!("loading {file}"))?;
            exes.push(exe);
        }
        Ok(PjrtEps { model: name.to_string(), dim, exes })
    }

    pub fn batch_sizes(&self) -> Vec<usize> {
        self.exes.iter().map(|e| e.batch).collect()
    }

    /// Pick the executable for the next chunk: the largest artifact that
    /// fits entirely (zero padding), else the smallest one that covers the
    /// tail (minimal padding). §Perf iteration 4: the previous
    /// smallest-that-covers policy padded merged batches up to 2.7x.
    fn exe_for(&self, n: usize) -> &Arc<EpsExecutable> {
        if let Some(exe) = self.exes.iter().rev().find(|e| e.batch <= n) {
            return exe;
        }
        let sizes = self.batch_sizes();
        let b = pick_batch(&sizes, n);
        self.exes.iter().find(|e| e.batch == b).unwrap()
    }
}

impl EpsModel for PjrtEps {
    fn dim(&self) -> usize {
        self.dim
    }

    fn eval(&self, x: &[f64], t: &[f64], b: usize, out: &mut [f64]) {
        let d = self.dim;
        let mut done = 0;
        while done < b {
            let exe = self.exe_for(b - done);
            let chunk = exe.batch.min(b - done);
            // Stage a padded f32 batch (pad rows repeat row 0 of the chunk).
            let mut xf = vec![0f32; exe.batch * d];
            let mut tf = vec![0f32; exe.batch];
            for i in 0..exe.batch {
                let src = if i < chunk { done + i } else { done };
                for j in 0..d {
                    xf[i * d + j] = x[src * d + j] as f32;
                }
                tf[i] = t[src] as f32;
            }
            let res = exe.run(&xf, &tf).expect("pjrt execute");
            let eps = &res[0];
            for i in 0..chunk {
                for j in 0..d {
                    out[(done + i) * d + j] = eps[i * d + j] as f64;
                }
            }
            done += chunk;
        }
    }
}
