//! # DEIS — Diffusion Exponential Integrator Sampler
//!
//! Production-shaped reproduction of *"Fast Sampling of Diffusion Models
//! with Exponential Integrator"* (Zhang & Chen, ICLR 2023) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the sampling service: solver library (every DEIS
//!   variant + every baseline the paper compares), coefficient machinery,
//!   time grids, dynamic-batching coordinator, PJRT runtime, metrics, NLL.
//! * **L2 (python/compile, build-time only)** — the ε-model in JAX, trained
//!   on synthetic datasets and AOT-lowered to HLO text artifacts.
//! * **L1 (python/compile/kernels)** — Pallas kernels for the hot spots
//!   (fused residual block, time embed, DEIS combine), interpret-mode.
//!
//! Python never runs on the request path: `Runtime` loads `artifacts/*.hlo.txt`
//! through PJRT and the coordinator serves batched sampling requests from
//! pure rust. See DESIGN.md for the experiment index and substitutions.

pub mod coordinator;
pub mod diffusion;
pub mod exp;
pub mod gmm;
pub mod likelihood;
pub mod metrics;
pub mod quad;
pub mod router;
pub mod runtime;
pub mod score;
pub mod server;
pub mod solvers;
pub mod tensor;
pub mod timegrid;
pub mod util;

pub use diffusion::Sde;
pub use solvers::{Solver, SolverKind};
pub use timegrid::GridKind;
