//! Exact data log-likelihood through the probability-flow ODE (paper App. B
//! Q1): integrate the augmented system
//!
//! ```text
//! dx/dt      = f(t) x + ½g²/σ · ε(x,t)
//! d logp/dt  = −∇·(dx/dt) = −(D·f(t) + ½g²/σ · tr ∂ε/∂x)
//! ```
//!
//! forward from (x₀, t₀) to T, then log p₀(x) = log π(x_T) + ∫ ∇·f dt. The
//! divergence comes from an [`EpsDivModel`]: the analytic GMM closed form or
//! the AOT `epsdiv_*` artifact (exact JVP trace). The paper's B.1 claim —
//! ρ3Kutta NLL converges ~4× faster than RK45 — is reproduced by running the
//! same augmented dynamics under a fixed ρ-grid Kutta scheme.

use crate::diffusion::Sde;
use crate::gmm::Gmm;

/// ε and its exact divergence, batched.
pub trait EpsDivModel: Send + Sync {
    fn dim(&self) -> usize;
    /// Writes eps into `eps` ([b*dim]) and tr ∂ε/∂x into `div` ([b]).
    fn eval_div(&self, x: &[f64], t: &[f64], b: usize, eps: &mut [f64], div: &mut [f64]);
}

pub struct GmmEpsDiv {
    pub gmm: Gmm,
    pub sde: Sde,
}

impl EpsDivModel for GmmEpsDiv {
    fn dim(&self) -> usize {
        self.gmm.dim()
    }

    fn eval_div(&self, x: &[f64], t: &[f64], b: usize, eps: &mut [f64], div: &mut [f64]) {
        self.gmm.eps(&self.sde, x, t, b, eps);
        div.copy_from_slice(&self.gmm.eps_div(&self.sde, x, t, b));
    }
}

/// Result of an NLL evaluation.
#[derive(Clone, Debug)]
pub struct NllResult {
    /// log p0(x) per sample (natural log).
    pub logp: Vec<f64>,
    /// bits/dim = −logp / (D ln 2).
    pub bits_per_dim: f64,
    pub nfe: usize,
}

/// Augmented derivative at scalar time t: writes dx into `dx` and returns
/// d(logp-deficit)/dt per row into `dl`.
fn aug_deriv(
    model: &dyn EpsDivModel,
    sde: &Sde,
    x: &[f64],
    t: f64,
    b: usize,
    tb: &mut Vec<f64>,
    eps: &mut [f64],
    divb: &mut [f64],
    dx: &mut [f64],
    dl: &mut [f64],
) {
    let d = model.dim();
    tb.clear();
    tb.resize(b, t);
    model.eval_div(x, tb, b, eps, divb);
    let f = sde.f_scalar(t);
    let w = 0.5 * sde.g2(t) / sde.sigma(t);
    for i in 0..b {
        for j in 0..d {
            dx[i * d + j] = f * x[i * d + j] + w * eps[i * d + j];
        }
        dl[i] = -(d as f64 * f + w * divb[i]);
    }
}

/// Fixed-grid NLL with RK4 in t over `grid` (3 NFE/step via shared stages? —
/// classic RK4 = 4 evals/step; we count truthfully).
pub fn nll_rk_t(model: &dyn EpsDivModel, sde: &Sde, grid: &[f64], x0: &[f64], b: usize) -> NllResult {
    let d = model.dim();
    let n = grid.len() - 1;
    let mut x = x0.to_vec();
    let mut logdef = vec![0.0; b]; // ∫ ∇·f dt accumulated (we add at the end)
    let mut tb = Vec::new();
    let (mut eps, mut divb) = (vec![0.0; b * d], vec![0.0; b]);
    let mut nfe = 0;

    let mut k_x: Vec<Vec<f64>> = (0..4).map(|_| vec![0.0; b * d]).collect();
    let mut k_l: Vec<Vec<f64>> = (0..4).map(|_| vec![0.0; b]).collect();
    let mut xs = vec![0.0; b * d];

    for i in 0..n {
        // integrate FORWARD: t_i -> t_{i+1}
        let (t, t_next) = (grid[i], grid[i + 1]);
        let h = t_next - t;
        let offsets = [0.0, 0.5, 0.5, 1.0];
        for s in 0..4 {
            xs.copy_from_slice(&x);
            if s > 0 {
                let c = offsets[s];
                for (xv, kv) in xs.iter_mut().zip(&k_x[s - 1]) {
                    *xv += h * c * kv;
                }
            }
            let (kx_head, kx_tail) = k_x.split_at_mut(s);
            let (kl_head, kl_tail) = k_l.split_at_mut(s);
            let _ = (kx_head, kl_head);
            aug_deriv(model, sde, &xs, t + offsets[s] * h, b, &mut tb, &mut eps, &mut divb,
                &mut kx_tail[0], &mut kl_tail[0]);
            nfe += 1;
        }
        for idx in 0..b * d {
            x[idx] += h / 6.0
                * (k_x[0][idx] + 2.0 * k_x[1][idx] + 2.0 * k_x[2][idx] + k_x[3][idx]);
        }
        for i2 in 0..b {
            // d logp/dt = -div f; logp(x0) = logp(xT) + ∫ div f dt, so track
            // +∫ div f = -∫ dl.
            logdef[i2] -=
                h / 6.0 * (k_l[0][i2] + 2.0 * k_l[1][i2] + 2.0 * k_l[2][i2] + k_l[3][i2]);
        }
    }

    // prior at T
    let t_max = grid[n];
    let prior_std = sde.prior_std(t_max);
    let mut logp = vec![0.0; b];
    let log_norm = -0.5 * (d as f64) * (2.0 * std::f64::consts::PI * prior_std * prior_std).ln();
    for i in 0..b {
        let mut sq = 0.0;
        for j in 0..d {
            let v = x[i * d + j];
            sq += v * v;
        }
        logp[i] = log_norm - 0.5 * sq / (prior_std * prior_std) + logdef[i];
    }
    let mean_logp = logp.iter().sum::<f64>() / b as f64;
    NllResult { bits_per_dim: -mean_logp / (d as f64 * std::f64::consts::LN_2), logp, nfe }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::timegrid::{build, GridKind};
    use crate::util::rng::Rng;

    #[test]
    fn nll_matches_exact_logp_on_gmm() {
        // For the analytic GMM the PF-ODE NLL must equal the closed-form
        // log p_{t0} (up to discretization + the tiny t0 gap).
        let sde = Sde::vp();
        let gmm = Gmm::ring2d(4.0, 8, 0.25);
        let model = GmmEpsDiv { gmm: gmm.clone(), sde };
        let mut rng = Rng::new(5);
        let b = 16;
        let x0 = gmm.sample(&mut rng, b);
        let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, 100);
        let res = nll_rk_t(&model, &sde, &grid, &x0, b);
        let exact = gmm.logp(&sde, &x0, 1e-3, b);
        for i in 0..b {
            assert!(
                (res.logp[i] - exact[i]).abs() < 0.05,
                "sample {i}: ode {} vs exact {}",
                res.logp[i],
                exact[i]
            );
        }
        assert_eq!(res.nfe, 400);
    }

    #[test]
    fn bits_per_dim_reasonable() {
        let sde = Sde::vp();
        let gmm = Gmm::ring2d(4.0, 8, 0.25);
        let model = GmmEpsDiv { gmm: gmm.clone(), sde };
        let mut rng = Rng::new(9);
        let x0 = gmm.sample(&mut rng, 32);
        let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, 60);
        let res = nll_rk_t(&model, &sde, &grid, &x0, 32);
        // differential entropy of the ring GMM ~ log(8) + entropy of N(0,.25^2 I)
        // in nats ~ 2.08 + (1 + ln(2π·0.0625)) ≈ ...; just sanity-range check.
        assert!(res.bits_per_dim > -3.0 && res.bits_per_dim < 3.0, "{}", res.bits_per_dim);
    }
}
