//! Table 2: the full DEIS variant grid (DDIM, rho2Heun, rho3Kutta, rho4RK,
//! rhoAB1-3, tAB1-3) x NFE {5,10,15,20,50} on the trained gmm2d model.

use deis::diffusion::Sde;
use deis::exp::{print_table, run_solver, sweep_model, QualityEval};
use deis::solvers::table2_kinds;
use deis::timegrid::GridKind;
use deis::util::bench::CsvSink;

fn main() {
    let sde = Sde::vp();
    let model = sweep_model("gmm2d");
    let eval = QualityEval::new("gmm2d", 20_000);
    let nfes = [5usize, 10, 15, 20, 50];
    let mut csv = CsvSink::new("table2.csv", "solver,nfe,nfe_spent,swd1000");
    let mut rows = Vec::new();
    for kind in table2_kinds() {
        let mut vals = Vec::new();
        for &nfe in &nfes {
            let (x, spent) =
                run_solver(&*model, &sde, kind, GridKind::Quadratic, 1e-3, nfe, 4000, 7);
            let q = eval.score(&x).swd1000;
            csv.row(&format!("{},{nfe},{spent},{q:.3}", kind.name()));
            vals.push(q);
        }
        rows.push((kind.name(), vals));
    }
    print_table(
        "Table 2: DEIS variants (SWDx1000, gmm2d, quadratic grid, t0=1e-3)",
        &nfes.iter().map(|n| format!("NFE {n}")).collect::<Vec<_>>(),
        &rows,
    );
    // Paper shape: tAB3 beats DDIM at small NFE; everything converges by 50.
    let ddim5 = rows[0].1[0];
    let tab3_5 = rows[9].1[0];
    println!("\nshape @ NFE=5: ddim {ddim5:.2} vs tab3 {tab3_5:.2} (paper: tab3 wins)");
}
