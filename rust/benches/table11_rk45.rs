//! Table 11: the blackbox adaptive RK45 baseline — tolerance sweep showing
//! NFE spent vs quality (decent at >= 50 NFE, poor under tight budgets).

use deis::diffusion::Sde;
use deis::exp::{sweep_model, QualityEval};
use deis::score::Counting;
use deis::solvers::rk45::Rk45;
use deis::solvers::Solver;
use deis::timegrid::{build, GridKind};
use deis::util::bench::CsvSink;
use deis::util::rng::Rng;

fn main() {
    let sde = Sde::vp();
    let model = sweep_model("gmm2d");
    let eval = QualityEval::new("gmm2d", 20_000);
    // t0 = 1e-3: the net's training range (paper uses 1e-4 with nets trained
    // to smaller t).
    let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, 10);
    let n = 3000;
    let mut csv = CsvSink::new("table11.csv", "tol,nfe,swd1000");
    println!("{:<12}{:>10}{:>12}", "tol", "NFE", "SWDx1000");
    for tol in [3e-1, 1e-1, 3e-2, 1e-2, 1e-3, 1e-4, 1e-5] {
        let counted = Counting::new(&*model);
        let solver = Rk45::new(&sde, &grid, tol, tol);
        let mut rng = Rng::new(7);
        let mut x = rng.normal_vec(n * 2);
        solver.sample(&counted, &mut x, n, &mut Rng::new(1));
        let q = eval.score(&x).swd1000;
        println!("{tol:<12.0e}{:>10}{q:>12.2}", counted.nfe());
        csv.row(&format!("{tol:e},{},{q:.3}", counted.nfe()));
    }
    println!("\npaper shape: RK45 needs ~50+ NFE for decent quality; DEIS reaches the \
              same at 10-20 (compare table2)");
}
