//! Table 10: uniform vs quadratic timestep schedules for the plain Euler
//! sampler. Paper uses t0 = 1e-4; our nets are trained on t in [1e-3, 1]
//! (sde.py), so we stop at 1e-3 — sampling below the training range only
//! adds out-of-distribution eps noise.

use deis::diffusion::Sde;
use deis::exp::{print_table, run_solver, sweep_model, QualityEval};
use deis::solvers::SolverKind;
use deis::timegrid::GridKind;
use deis::util::bench::CsvSink;

fn main() {
    let sde = Sde::vp();
    let model = sweep_model("gmm2d");
    let eval = QualityEval::new("gmm2d", 20_000);
    let nfes = [5usize, 10, 20, 50, 100, 200, 500];
    let mut csv = CsvSink::new("table10.csv", "grid,nfe,swd1000");
    let mut rows = Vec::new();
    for (label, grid) in [("uniform", GridKind::Uniform), ("quadratic", GridKind::Quadratic)] {
        let mut vals = Vec::new();
        for &nfe in &nfes {
            let (x, _) = run_solver(&*model, &sde, SolverKind::Euler, grid, 1e-3, nfe, 3000, 7);
            let q = eval.score(&x).swd1000;
            csv.row(&format!("{label},{nfe},{q:.3}"));
            vals.push(q);
        }
        rows.push((label.to_string(), vals));
    }
    print_table(
        "Table 10: Euler timestep schedule (SWDx1000, t0=1e-3)",
        &nfes.iter().map(|n| format!("NFE {n}")).collect::<Vec<_>>(),
        &rows,
    );
    println!("\npaper shape: small-NFE and large-NFE regimes prefer different schedules");
}
