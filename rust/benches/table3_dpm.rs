//! Table 3: DEIS vs DPM-Solver on the ImageNet64 stand-in (img8), matching
//! pairs at equal order: tAB/rhoAB vs DPM-Solver2 (rho-midpoint) vs
//! DPM-Solver3 (rho-kutta3), log-rho grid as in the paper's App. H.7.

use deis::diffusion::Sde;
use deis::exp::{print_table, run_solver, sweep_model, QualityEval};
use deis::solvers::SolverKind;
use deis::timegrid::GridKind;
use deis::util::bench::CsvSink;

fn main() {
    let sde = Sde::vp();
    let model = sweep_model("img8");
    let eval = QualityEval::new("img8", 4000);
    let nfes = [10usize, 12, 16, 20, 30, 50];
    let kinds = [
        SolverKind::Tab(2),
        SolverKind::RhoAb(2),
        SolverKind::Dpm(2),
        SolverKind::RhoMidpoint,
        SolverKind::Dpm(3),
        SolverKind::RhoKutta3,
    ];
    let mut csv = CsvSink::new("table3.csv", "solver,nfe,swd1000");
    let mut rows = Vec::new();
    for kind in kinds {
        let mut vals = Vec::new();
        for &nfe in &nfes {
            let (x, _) = run_solver(&*model, &sde, kind, GridKind::LogRho, 1e-3, nfe, 800, 7);
            let q = eval.score(&x).swd1000;
            csv.row(&format!("{},{nfe},{q:.3}", kind.name()));
            vals.push(q);
        }
        rows.push((kind.name(), vals));
    }
    print_table(
        "Table 3: DEIS vs DPM-Solver (SWDx1000, img8, log-rho grid)",
        &nfes.iter().map(|n| format!("NFE {n}")).collect::<Vec<_>>(),
        &rows,
    );
    println!("\npaper shape: multistep tAB best at lowest NFE; gaps close by NFE 30-50");
}
