//! Fig. 2: the learned score is only accurate where p_t(x) is large.
//! On toy1d we have the exact score, so the fitting error of the trained
//! net is measured on an (x, t) grid and summarized by density band.

use deis::diffusion::Sde;
use deis::exp::sweep_model;
use deis::gmm::Gmm;
use deis::score::EpsModel;
use deis::util::bench::CsvSink;

fn main() {
    let sde = Sde::vp();
    let gmm = Gmm::new(vec![vec![0.0]], 0.05); // concentrated 1-D Gaussian
    let net = sweep_model("toy1d");
    let mut csv = CsvSink::new("fig2_fitting_error.csv", "t,x,err,logp");

    let mut band_hi = (0.0, 0usize); // high-density region
    let mut band_lo = (0.0, 0usize); // low-density region
    for ti in 1..=20 {
        let t = ti as f64 / 20.0;
        for xi in 0..=60 {
            let x = -6.0 + 12.0 * xi as f64 / 60.0;
            let mut exact = vec![0.0];
            gmm.eps(&sde, &[x], &[t], 1, &mut exact);
            let got = net.eval_vec(&[x], &[t], 1);
            let err = (got[0] - exact[0]).abs();
            let lp = gmm.logp(&sde, &[x], t, 1)[0];
            csv.row(&format!("{t:.3},{x:.3},{err:.5},{lp:.3}"));
            // "high density" = within 2 std of the marginal
            let var = sde.abar(t) * 0.0025 + sde.sigma(t).powi(2);
            if x * x < 4.0 * var {
                band_hi.0 += err;
                band_hi.1 += 1;
            } else if x * x > 9.0 * var {
                band_lo.0 += err;
                band_lo.1 += 1;
            }
        }
    }
    let hi = band_hi.0 / band_hi.1 as f64;
    let lo = band_lo.0 / band_lo.1 as f64;
    println!("Fig 2 — fitting error of the trained toy1d net vs exact score:");
    println!("  mean |eps_net - eps*| in high-density region (|x| < 2σ): {hi:.4}");
    println!("  mean |eps_net - eps*| in low-density  region (|x| > 3σ): {lo:.4}");
    println!("  ratio low/high: {:.1}x  (paper: error explodes off-manifold)", lo / hi);
    assert!(lo > hi, "fitting error should be worse off-distribution");
    println!("CSV: results/fig2_fitting_error.csv");
}
