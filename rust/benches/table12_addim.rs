//! Table 12: Analytic-DDIM (Bao et al. 2022) vs iPNDM vs tAB-DEIS, plus the
//! paper's note that A-DDIM leans on the x0-clipping trick at low NFE.

use deis::diffusion::Sde;
use deis::exp::{print_table, run_solver, sweep_model, QualityEval};
use deis::solvers::sde_samplers::ADdim;
use deis::solvers::{Solver, SolverKind};
use deis::timegrid::{build, GridKind};
use deis::util::bench::CsvSink;
use deis::util::rng::Rng;

fn main() {
    let sde = Sde::vp();
    let model = sweep_model("gmm2d");
    let eval = QualityEval::new("gmm2d", 20_000);
    let nfes = [5usize, 10, 20, 50];
    let kinds = [
        SolverKind::ADdim,
        SolverKind::Ipndm(1),
        SolverKind::Ipndm(2),
        SolverKind::Ipndm(3),
        SolverKind::Tab(1),
        SolverKind::Tab(2),
        SolverKind::Tab(3),
    ];
    let mut csv = CsvSink::new("table12.csv", "solver,nfe,swd1000");
    let mut rows = Vec::new();
    for kind in kinds {
        let mut vals = Vec::new();
        for &nfe in &nfes {
            let (x, _) = run_solver(&*model, &sde, kind, GridKind::Quadratic, 1e-3, nfe, 4000, 7);
            let q = eval.score(&x).swd1000;
            csv.row(&format!("{},{nfe},{q:.3}", kind.name()));
            vals.push(q);
        }
        rows.push((kind.name(), vals));
    }
    print_table("Table 12: A-DDIM vs iPNDM vs tAB-DEIS (SWDx1000, gmm2d)",
        &nfes.iter().map(|n| format!("NFE {n}")).collect::<Vec<_>>(), &rows);

    // Clipping ablation (paper: "A-DDIM does not provide high-quality
    // samples without proper clipping when NFE is low").
    println!("\nA-DDIM x0-clipping ablation @ NFE=10:");
    for clip in [Some(6.0), None] {
        let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, 10);
        let mut solver = ADdim::new(&sde, &grid);
        solver.clip = clip;
        let mut x = Rng::new(7).normal_vec(4000 * 2);
        solver.sample(&*model, &mut x, 4000, &mut Rng::new(1));
        println!("  clip={clip:?}: SWDx1000 {:.2}", eval.score(&x).swd1000);
    }
}
