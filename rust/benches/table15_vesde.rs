//! Table 15: tAB0-3 under VESDE (exact-score oracle; the paper's VE nets are
//! VP-incompatible checkpoints — our trained nets use VP, so the oracle
//! isolates the VE discretization behaviour the table is about).

use deis::diffusion::Sde;
use deis::exp::{print_table, QualityEval};
use deis::gmm::Gmm;
use deis::score::GmmEps;
use deis::solvers::{self, SolverKind};
use deis::timegrid::{build, GridKind};
use deis::util::bench::CsvSink;
use deis::util::rng::Rng;

fn main() {
    let sde = Sde::ve();
    let model = GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), sde);
    let eval = QualityEval::new("gmm2d", 20_000);
    let nfes = [5usize, 10, 20, 50];
    let mut csv = CsvSink::new("table15.csv", "solver,nfe,swd1000");
    let mut rows = Vec::new();
    for order in 0..=3usize {
        let mut vals = Vec::new();
        for &nfe in &nfes {
            let grid = build(GridKind::LogRho, &sde, 1e-5, 1.0, nfe);
            let solver = solvers::build(SolverKind::Tab(order), &sde, &grid);
            let n = 4000;
            let mut rng = Rng::new(7);
            let prior = sde.prior_std(1.0);
            let mut x: Vec<f64> = (0..n * 2).map(|_| prior * rng.normal()).collect();
            solver.sample(&model, &mut x, n, &mut Rng::new(1));
            let q = eval.score(&x).swd1000;
            csv.row(&format!("tab{order},{nfe},{q:.3}"));
            vals.push(q);
        }
        rows.push((format!("tAB{order}"), vals));
    }
    print_table("Table 15: VESDE tAB-DEIS (SWDx1000, exact score, log-rho grid)",
        &nfes.iter().map(|n| format!("NFE {n}")).collect::<Vec<_>>(), &rows);
    println!("\npaper shape: VE is much harder at low NFE than VP (compare table2)");
}
