//! Fig. 7: quality-vs-NFE curves for the headline samplers across datasets
//! (trained nets; CelebA/ImageNet stand-ins per DESIGN.md §1).

use deis::diffusion::Sde;
use deis::exp::{print_table, run_solver, sweep_model, QualityEval};
use deis::solvers::SolverKind;
use deis::timegrid::GridKind;
use deis::util::bench::CsvSink;

fn main() {
    let sde = Sde::vp();
    let nfes = [5usize, 10, 20, 50];
    let kinds = [
        SolverKind::Tab(0),
        SolverKind::Tab(3),
        SolverKind::Ipndm(3),
        SolverKind::Dpm(2),
        SolverKind::RhoHeun,
    ];
    let mut csv = CsvSink::new("fig7_curves.csv", "dataset,solver,nfe,swd1000");
    for (dataset, n) in [("gmm2d", 4000), ("spiral2d", 4000), ("img8", 800)] {
        let model = sweep_model(dataset);
        let eval = QualityEval::new(dataset, if dataset == "img8" { 4000 } else { 20_000 });
        let mut rows = Vec::new();
        for kind in kinds {
            let mut vals = Vec::new();
            for &nfe in &nfes {
                let (x, _) =
                    run_solver(&*model, &sde, kind, GridKind::Quadratic, 1e-3, nfe, n, 7);
                let q = eval.score(&x).swd1000;
                csv.row(&format!("{dataset},{},{nfe},{q:.3}", kind.name()));
                vals.push(q);
            }
            rows.push((kind.name(), vals));
        }
        print_table(
            &format!("Fig 7: SWDx1000 vs NFE ({dataset})"),
            &nfes.iter().map(|n| format!("NFE {n}")).collect::<Vec<_>>(),
            &rows,
        );
    }
}
