//! Fig. 4c: sample quality vs N for tAB-DEIS polynomial orders r = 0..3 —
//! higher order pays off at small N (exact-score oracle + trained net).

use deis::diffusion::Sde;
use deis::exp::{print_table, run_solver, sweep_model, QualityEval};
use deis::solvers::SolverKind;
use deis::timegrid::GridKind;
use deis::util::bench::CsvSink;

fn main() {
    let sde = Sde::vp();
    let eval = QualityEval::new("gmm2d", 20_000);
    let ns = [5usize, 10, 15, 20, 50];
    let mut csv = CsvSink::new("fig4c_order_vs_n.csv", "backend,order,n,swd1000");
    for backend in ["gmm2d_oracle", "gmm2d"] {
        let model = sweep_model(backend);
        let mut rows = Vec::new();
        for order in 0..=3usize {
            let mut vals = Vec::new();
            for &n in &ns {
                let (x, _) = run_solver(&*model, &sde, SolverKind::Tab(order),
                    GridKind::Quadratic, 1e-3, n, 4000, 7);
                let q = eval.score(&x).swd1000;
                csv.row(&format!("{backend},{order},{n},{q:.3}"));
                vals.push(q);
            }
            rows.push((format!("tAB r={order}"), vals));
        }
        print_table(
            &format!("Fig 4c: SWDx1000 vs N by order ({backend})"),
            &ns.iter().map(|n| format!("N={n}")).collect::<Vec<_>>(),
            &rows,
        );
    }
}
