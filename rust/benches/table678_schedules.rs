//! Tables 6/7/8: t0 x time-scheduling sweep — Eq.(42) power-kappa in t,
//! Eq.(43) kappa=7 in rho (Karras), Eq.(44) uniform log-rho — for DDIM,
//! rho2Heun, rhoAB3, tAB3.

use deis::diffusion::Sde;
use deis::exp::{print_table, run_solver, sweep_model, QualityEval};
use deis::solvers::SolverKind;
use deis::timegrid::GridKind;
use deis::util::bench::CsvSink;

fn main() {
    let sde = Sde::vp();
    let model = sweep_model("gmm2d");
    let eval = QualityEval::new("gmm2d", 20_000);
    let nfes = [5usize, 10, 20, 50];
    let grids = [
        GridKind::PowerT(1.0),
        GridKind::PowerT(2.0),
        GridKind::PowerT(3.0),
        GridKind::PowerRho(7.0),
        GridKind::LogRho,
    ];
    let kinds =
        [SolverKind::Tab(0), SolverKind::RhoHeun, SolverKind::RhoAb(3), SolverKind::Tab(3)];
    let mut csv = CsvSink::new("table678.csv", "t0,grid,solver,nfe,swd1000");
    for t0 in [1e-3, 1e-4] {
        for grid in grids {
            let mut rows = Vec::new();
            for kind in kinds {
                let mut vals = Vec::new();
                for &nfe in &nfes {
                    let (x, _) = run_solver(&*model, &sde, kind, grid, t0, nfe, 3000, 7);
                    let q = eval.score(&x).swd1000;
                    csv.row(&format!("{t0:e},{},{},{nfe},{q:.3}", grid.name(), kind.name()));
                    vals.push(q);
                }
                rows.push((kind.name(), vals));
            }
            print_table(
                &format!("Tables 6-8: t0={t0:e}, grid={}", grid.name()),
                &nfes.iter().map(|n| format!("NFE {n}")).collect::<Vec<_>>(),
                &rows,
            );
        }
    }
    println!("\npaper shape: schedules matter enormously at low NFE; different solvers \
              prefer different grids (tAB likes t-power2, rhoRK likes log-rho/karras)");
}
