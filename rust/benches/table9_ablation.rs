//! Table 9 (quantitative Fig. 5): the ingredient ablation ladder on the
//! trained model, Euler -> +EI -> +eps -> +poly -> +opt{t_i}, plus EM.

use deis::diffusion::Sde;
use deis::exp::{print_table, run_solver, sweep_model, QualityEval};
use deis::solvers::SolverKind;
use deis::timegrid::GridKind;
use deis::util::bench::CsvSink;

fn main() {
    // Two substrates: the trained net (fitting + discretization error, the
    // paper's setting) and the *concentrated* exact-score oracle, where the
    // stiffness that separates the ladder lives (DESIGN.md §1 — image data
    // is manifold-concentrated; smooth 2-D data alone is not stiff).
    ladder_on("gmm2d", "gmm2d");
    ladder_on("gmm2d_sharp_oracle", "gmm2d_sharp");
}

fn ladder_on(model_name: &str, dataset: &str) {
    let sde = Sde::vp();
    let model = sweep_model(model_name);
    let eval = QualityEval::new(dataset, 20_000);
    let nfes = [5usize, 10, 20, 30, 50, 100, 200];
    let ladder: Vec<(&str, SolverKind, GridKind)> = vec![
        ("euler", SolverKind::Euler, GridKind::Uniform),
        ("+EI", SolverKind::EiScore, GridKind::Uniform),
        ("+eps", SolverKind::Tab(0), GridKind::Uniform),
        ("+poly", SolverKind::Tab(3), GridKind::Uniform),
        ("+opt{t_i}", SolverKind::Tab(3), GridKind::Quadratic),
        ("em", SolverKind::EulerMaruyama, GridKind::Uniform),
    ];
    let mut csv = CsvSink::new("table9.csv", "model,ingredient,nfe,swd1000");
    let mut rows = Vec::new();
    for (label, kind, grid) in &ladder {
        let mut vals = Vec::new();
        for &nfe in &nfes {
            let (x, _) = run_solver(&*model, &sde, *kind, *grid, 1e-3, nfe, 4000, 7);
            let q = eval.score(&x).swd1000;
            csv.row(&format!("{model_name},{label},{nfe},{q:.3}"));
            vals.push(q);
        }
        rows.push((label.to_string(), vals));
    }
    print_table(
        &format!("Table 9: ingredient ablation (SWDx1000, {model_name})"),
        &nfes.iter().map(|n| format!("NFE {n}")).collect::<Vec<_>>(),
        &rows,
    );
    // Paper shape at NFE=10: EI(score) worse than Euler; each later
    // ingredient improves.
    let at10: Vec<f64> = rows.iter().map(|r| r.1[1]).collect();
    println!(
        "\nshape @ NFE=10: euler {:.1} | +EI {:.1} (worse!) | +eps {:.1} | +poly {:.1} | +opt {:.1}",
        at10[0], at10[1], at10[2], at10[3], at10[4]
    );
}
