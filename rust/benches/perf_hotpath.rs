//! §Perf microbenches for the three layers (criterion-style, in-repo
//! harness): PJRT dispatch (pallas vs xla lowering), native-MLP forward
//! (generic-t and the solver-shaped uniform-t fast path), the DEIS combine,
//! coefficient precomputation, and coordinator overhead — including the
//! step-level scheduler's co-batched serving path. Results feed
//! EXPERIMENTS.md §Perf/§Serving, plus `BENCH_hotpath.json` at the repo
//! root so future PRs (and the CI bench-smoke artifact) can diff the perf
//! trajectory mechanically.
//!
//! `-- --quick` (or DEIS_BENCH_QUICK=1) runs every bench on a smoke budget:
//! CI uses it to prove the harness executes end-to-end. Sections whose
//! backend is unavailable in the current environment (PJRT without the xla
//! crate, native nets without `make artifacts`) are skipped with a notice
//! instead of panicking, so the bench is runnable everywhere.

use std::sync::Arc;
use std::time::Duration;

use deis::coordinator::{
    Coordinator, CoordinatorConfig, ModelRegistry, SampleRequest, SampleResult,
};
use deis::diffusion::Sde;
use deis::gmm::Gmm;
use deis::runtime::Runtime;
use deis::score::{pjrt::PjrtEps, EpsModel, GmmEps, NativeMlp, Precision};
use deis::server::{self, wire, wire::Frame, wire::ReplyMeta};
use deis::solvers::{self, deis_combine, SolverKind};
use deis::tensor::{fma_supported, Kernel, KernelPath, Mat};
use deis::timegrid::{build, GridKind};
use deis::util::bench::{bench_for, black_box, budget_or_quick, CsvSink, JsonSink};
use deis::util::json::Json;
use deis::util::rng::Rng;

fn main() {
    let mut csv = CsvSink::new("perf_hotpath.csv", "bench,mean_us,p50_us,p99_us");
    // Anchor the JSON at the repo root (one above the crate dir) regardless
    // of the invocation cwd, so successive PRs diff the same file.
    let json_path = option_env!("CARGO_MANIFEST_DIR")
        .map(|d| format!("{d}/../BENCH_hotpath.json"))
        .unwrap_or_else(|| "BENCH_hotpath.json".into());
    let mut json = JsonSink::new(&json_path);
    let budget = budget_or_quick(Duration::from_millis(1500));
    let mut log = |s: deis::util::bench::BenchStats| {
        println!("{s}");
        csv.row(&format!("{},{:.1},{:.1},{:.1}", s.name, s.mean_us(),
            s.p50.as_secs_f64() * 1e6, s.p99.as_secs_f64() * 1e6));
        json.add(&s);
    };

    let rt = Runtime::global();
    let mut rng = Rng::new(1);

    // --- L0: tensor kernels, per path and precision -------------------------
    // The eps-net hot loop in isolation (§Kernels): one fused matmul+GELU at
    // the serving shape b=256, k=n=64, on each kernel path via an explicit
    // `run_with` (no process-global force). Acceptance row: tiled f64 must
    // beat the reference scalar kernel; the FMA rows appear only where the
    // CPU supports AVX2+FMA.
    {
        let (b, k, n) = (256, 64, 64);
        let x64 = rng.normal_vec(b * k);
        let w64 = Mat::from_rows(k, n, rng.normal_vec(k * n));
        let bias64 = rng.normal_vec(n);
        let mut out64 = vec![0.0f64; b * n];
        let x32: Vec<f32> = x64.iter().map(|&v| v as f32).collect();
        let w32 = Mat::<f32>::from_f64_rows(k, n, &w64.data);
        let bias32: Vec<f32> = bias64.iter().map(|&v| v as f32).collect();
        let mut out32 = vec![0.0f32; b * n];
        let kern = Kernel::overwrite_gelu();
        let mut f64_paths = vec![
            (KernelPath::Reference, "scalar reference"),
            (KernelPath::Tiled, "tiled"),
        ];
        if fma_supported() {
            f64_paths.push((KernelPath::Fma, "fma"));
        }
        for (path, label) in &f64_paths {
            log(bench_for(
                &format!("kernel matmul+gelu b256 k64 n64 f64 {label}"),
                budget,
                || {
                    kern.run_with(*path, &x64, k, &w64, &bias64, &mut out64);
                    black_box(&out64);
                },
            ));
        }
        let mut f32_paths = vec![(KernelPath::Tiled, "tiled")];
        if fma_supported() {
            f32_paths.push((KernelPath::Fma, "fma"));
        }
        for (path, label) in &f32_paths {
            log(bench_for(
                &format!("kernel matmul+gelu b256 k64 n64 f32 {label}"),
                budget,
                || {
                    kern.run_with(*path, &x32, k, &w32, &bias32, &mut out32);
                    black_box(&out32);
                },
            ));
        }
    }

    // --- L0: native forward, f64 vs f32 engine (synthetic weights) ---------
    // Artifact-independent end-to-end engine rows: the same synthetic net at
    // both precisions, uniform-t (the solver-step shape). The f32/f64 ratio
    // here is the headline number for the opt-in f32 inference mode.
    {
        let root = Json::parse(&synthetic_weights_json(&mut rng, 8, 64, 16, 3)).unwrap();
        let b = 256;
        let x = rng.normal_vec(b * 8);
        let t_uni = vec![0.5; b];
        let mut out = vec![0.0; b * 8];
        for precision in [Precision::F64, Precision::F32] {
            let net = NativeMlp::from_json_with(&root, precision).unwrap();
            log(bench_for(
                &format!("native mlp synthetic b256 h64 uniform-t {}", precision.name()),
                budget,
                || {
                    net.eval(&x, &t_uni, b, &mut out);
                    black_box(&out);
                },
            ));
        }
    }

    // --- L1/L2: PJRT execution, pallas-kernel vs plain-XLA lowering -------
    for (name, label, d) in [
        ("gmm2d", "pjrt eval b256 (pallas kernels)", 2),
        ("gmm2d_xla", "pjrt eval b256 (xla oracle)", 2),
        ("img8", "pjrt eval b256 img8 (pallas)", 64),
    ] {
        let model = match PjrtEps::load(rt, name, &[256]) {
            Ok(m) => m,
            Err(e) => {
                eprintln!("skipping '{label}': {e:#}");
                continue;
            }
        };
        let x = rng.normal_vec(256 * d);
        let t: Vec<f64> = (0..256).map(|_| rng.uniform_in(0.01, 1.0)).collect();
        let mut out = vec![0.0; 256 * d];
        log(bench_for(label, budget, || {
            model.eval(&x, &t, 256, &mut out);
            black_box(&out);
        }));
    }

    // --- L3: native MLP forward -------------------------------------------
    // Per-row random t exercises the generic path; the uniform-t variant is
    // what every solver step actually issues (cursor evals broadcast a
    // scalar) and takes the shared-embedding fast path.
    // DEIS_ARTIFACTS-aware, cwd-independent resolution: artifacts live in
    // <crate dir>/artifacts (where `make artifacts` writes and where the
    // integration tests, which run with cwd = crate dir, expect them).
    let art_dir = std::env::var("DEIS_ARTIFACTS").unwrap_or_else(|_| {
        option_env!("CARGO_MANIFEST_DIR")
            .map(|d| format!("{d}/artifacts"))
            .unwrap_or_else(|| "artifacts".into())
    });
    for name in ["gmm2d", "img8"] {
        let path = format!("{art_dir}/weights_{name}.json");
        let model = match NativeMlp::load(&path) {
            Ok(m) => Box::new(m) as Box<dyn EpsModel>,
            Err(e) => {
                eprintln!("skipping 'native mlp eval b256 {name}': {e:#}");
                continue;
            }
        };
        let d = model.dim();
        let x = rng.normal_vec(256 * d);
        let t: Vec<f64> = (0..256).map(|_| rng.uniform_in(0.01, 1.0)).collect();
        let mut out = vec![0.0; 256 * d];
        log(bench_for(&format!("native mlp eval b256 {name}"), budget, || {
            model.eval(&x, &t, 256, &mut out);
            black_box(&out);
        }));
        let t_uni = vec![0.5; 256];
        log(bench_for(&format!("native mlp eval b256 {name} uniform-t"), budget, || {
            model.eval(&x, &t_uni, 256, &mut out);
            black_box(&out);
        }));
    }

    // --- L3: analytic oracle (lower bound on eps cost) ----------------------
    {
        let model = GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp());
        let x = rng.normal_vec(256 * 2);
        let t: Vec<f64> = (0..256).map(|_| rng.uniform_in(0.01, 1.0)).collect();
        let mut out = vec![0.0; 512];
        log(bench_for("analytic gmm eps b256", budget, || {
            model.eval(&x, &t, 256, &mut out);
            black_box(&out);
        }));
    }

    // --- L3: coefficient precompute + combine -------------------------------
    {
        let sde = Sde::vp();
        log(bench_for("tab3 plan build (N=20)", budget, || {
            let grid = build(GridKind::Quadratic, &sde, 1e-3, 1.0, 20);
            black_box(solvers::build(SolverKind::Tab(3), &sde, &grid));
        }));
        let mut x = rng.normal_vec(256 * 64);
        let eps: Vec<Vec<f64>> = (0..4).map(|_| rng.normal_vec(256 * 64)).collect();
        let eps_refs: Vec<&[f64]> = eps.iter().map(|e| e.as_slice()).collect();
        log(bench_for("deis combine b256 d64 r3", budget, || {
            deis_combine(&mut x, 0.99, &[0.1, -0.2, 0.05, 0.01], &eps_refs);
            black_box(&x);
        }));
    }

    // --- L3: coordinator overhead (oracle model, tiny work) ----------------
    {
        let mut reg = ModelRegistry::new();
        reg.insert("gmm2d", Arc::new(GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp())));
        let coord = Coordinator::new(CoordinatorConfig::default(), reg);
        log(bench_for("coordinator roundtrip (n=1, nfe=1)", budget, || {
            let req = SampleRequest::new("gmm2d", SolverKind::Tab(0), 1, 1);
            black_box(coord.sample_blocking(req).unwrap());
        }));
        // Step-level scheduler: 8 concurrent same-config clients; their
        // per-step evals co-batch into one model call each (occupancy 8),
        // which is the headline serving win of the scheduler refactor.
        log(bench_for("scheduler 8-way co-batched (n=32, nfe=10)", budget, || {
            let rxs: Vec<_> = (0..8)
                .map(|i| {
                    let mut req = SampleRequest::new("gmm2d", SolverKind::Tab(2), 10, 32);
                    req.seed = i;
                    coord.submit(req)
                })
                .collect();
            for rx in rxs {
                black_box(rx.recv().unwrap().unwrap());
            }
        }));
        // Mixed-solver 8-way: solvers that used to take the blocking
        // fallback (adaptive rk45, stochastic Euler–Maruyama) alongside
        // tAB/DPM — tracks the fallback-free universal-cursor path, with
        // plan-cache lookups on every admission after the first round.
        log(bench_for("scheduler mixed-solver 8-way (n=32, nfe=10)", budget, || {
            let kinds = [
                SolverKind::Tab(2),
                SolverKind::Dpm(2),
                SolverKind::Rk45,
                SolverKind::EulerMaruyama,
                SolverKind::Tab(2),
                SolverKind::Dpm(2),
                SolverKind::Rk45,
                SolverKind::EulerMaruyama,
            ];
            let rxs: Vec<_> = kinds
                .iter()
                .enumerate()
                .map(|(i, &kind)| {
                    let mut req = SampleRequest::new("gmm2d", kind, 10, 32);
                    req.seed = i as u64;
                    coord.submit(req)
                })
                .collect();
            for rx in rxs {
                black_box(rx.recv().unwrap().unwrap());
            }
        }));
        coord.shutdown();
    }

    // --- L3: scheduler under slot-count + lock contention -------------------
    {
        // 64 key-distinct tiny flights (4 solvers x 16 NFEs, n=2 each) over
        // 4 workers: per-eval work is minimal, so the round-trip cost is
        // dominated by scheduler bookkeeping — admission, ready-index
        // anchor/member selection, checkout/re-slot — and by how much of
        // the scatter+advance runs outside the coordinator mutex. This is
        // the row that tracks the off-lock advance + ready-index win
        // (BENCH_hotpath.json diff vs the parent commit).
        let mut reg = ModelRegistry::new();
        reg.insert("gmm2d", Arc::new(GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp())));
        let coord = Coordinator::new(
            CoordinatorConfig { workers: 4, ..Default::default() },
            reg,
        );
        log(bench_for("scheduler 64-flight contended (n=2, 4 workers)", budget, || {
            run_contended_single_model(&coord);
        }));
        coord.shutdown();
    }

    // --- L3: sharded scheduler, multi-model contention ----------------------
    {
        // Same 64-flight contended shape, but split over 4 registered
        // models: with per-model sharding each model's 16 flights run on
        // their own mutex/ready-index/queue, so this row vs the
        // single-model row above quantifies the sharding win (and the
        // worker-stealing overhead) under identical total work.
        let mut reg = ModelRegistry::new();
        for name in ["gmm2d_a", "gmm2d_b", "gmm2d_c", "gmm2d_d"] {
            reg.insert(name, Arc::new(GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp())));
        }
        let coord = Coordinator::new(
            CoordinatorConfig { workers: 4, ..Default::default() },
            reg,
        );
        log(bench_for("scheduler 4-model contended (n=2, 4 workers)", budget, || {
            let kinds =
                [SolverKind::Tab(1), SolverKind::Tab(2), SolverKind::Dpm(1), SolverKind::Euler];
            let names = ["gmm2d_a", "gmm2d_b", "gmm2d_c", "gmm2d_d"];
            let rxs: Vec<_> = (0..64)
                .map(|i| {
                    // 16 flights per model, every (solver, nfe) distinct
                    // within its model: no admission merging, so all 64
                    // trajectories hold their own flight slots — but spread
                    // over 4 shards instead of contending on one lock.
                    // Model i%4 gets flight j = i/4 with nfe 8+j, which
                    // reproduces the single-model row's exact nfe multiset
                    // (each of 8..=23 four times) so the two rows time
                    // identical total work.
                    let mut req = SampleRequest::new(
                        names[i % 4],
                        kinds[(i / 4) % 4],
                        8 + i / 4,
                        2,
                    );
                    req.seed = i as u64;
                    coord.submit(req)
                })
                .collect();
            for rx in rxs {
                black_box(rx.recv().unwrap().unwrap());
            }
        }));
        coord.shutdown();
    }

    // --- L4: serving frontend wire costs ------------------------------------
    // Request parse (zero-copy scanner vs owned tree — the same line, so the
    // delta is pure allocation/tree cost), reply encode at the serving shape
    // b=256 d=2 in both frames, and a full localhost round-trip through the
    // readiness-driven event loop (results feed EXPERIMENTS.md §Serving).
    {
        let line = concat!(
            r#"{"model":"gmm2d","solver":"tab3","grid":"quadratic","nfe":10,"#,
            r#""n":256,"seed":12345,"t0":0.001,"sde":"vp","return_samples":true,"#,
            r#""deadline_ms":500,"dtype":"f64","frame":"bin"}"#
        );
        log(bench_for("wire parse submit-line (zero-copy)", budget, || {
            black_box(wire::parse_submit_fast(line).unwrap());
        }));
        log(bench_for("wire parse submit-line (owned tree)", budget, || {
            let v = Json::parse(line).unwrap();
            black_box(wire::submit_args_from_json(&v).unwrap());
        }));

        let res: anyhow::Result<SampleResult> = Ok(SampleResult {
            samples: rng.normal_vec(256 * 2),
            dim: 2,
            nfe: 10,
            merged_with: 3,
            co_batched: 5,
            queue_us: 120,
            solve_us: 5300,
        });
        for (frame, label) in [(Frame::Json, "json"), (Frame::Bin, "bin")] {
            let meta = ReplyMeta {
                n: 256,
                dtype: Precision::F64,
                return_samples: true,
                frame,
            };
            let mut out: Vec<u8> = Vec::new();
            log(bench_for(&format!("wire write response b256 {label}"), budget, || {
                out.clear();
                wire::write_reply(&mut out, &meta, &res);
                black_box(&out);
            }));
        }

        let mut reg = ModelRegistry::new();
        reg.insert("gmm2d", Arc::new(GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp())));
        let coord = Arc::new(Coordinator::new(CoordinatorConfig::default(), reg));
        let addr = server::serve(coord.clone(), "127.0.0.1:0").unwrap();
        let mut client = server::Client::connect(addr).unwrap();
        let req =
            Json::parse(r#"{"model":"gmm2d","solver":"tab0","nfe":1,"n":256}"#).unwrap();
        log(bench_for("server round-trip localhost n=256", budget, || {
            black_box(client.call(&req).unwrap());
        }));

        // Same request through the router tier over two workers: the delta
        // against the direct row above is the router's added hop cost
        // (parse-one-key + two relays on one event loop).
        let mut reg2 = ModelRegistry::new();
        reg2.insert("gmm2d", Arc::new(GmmEps::new(Gmm::ring2d(4.0, 8, 0.25), Sde::vp())));
        let coord2 = Arc::new(Coordinator::new(CoordinatorConfig::default(), reg2));
        let addr2 = server::serve(coord2.clone(), "127.0.0.1:0").unwrap();
        let raddr = deis::router::serve(
            vec![addr.to_string(), addr2.to_string()],
            "127.0.0.1:0",
        )
        .unwrap();
        let mut rclient = server::Client::connect(raddr).unwrap();
        log(bench_for("router round-trip localhost n=256", budget, || {
            black_box(rclient.call(&req).unwrap());
        }));
        // The serve() I/O threads hold clones; a failed unwrap just means
        // process exit reaps them (same as `deis serve`).
        for c in [coord, coord2] {
            if let Ok(c) = Arc::try_unwrap(c) {
                c.shutdown();
            }
        }
    }

    drop(log);
    if let Err(e) = json.flush() {
        eprintln!("warning: could not write BENCH_hotpath.json: {e}");
    }
}

/// Deterministic synthetic eps-net weights JSON (values ~N(0, 0.15) — small
/// enough that a 3-block net stays well-conditioned), so the kernel rows
/// run without `make artifacts`.
fn synthetic_weights_json(
    rng: &mut Rng,
    dim: usize,
    hidden: usize,
    embed: usize,
    n_blocks: usize,
) -> String {
    fn vec_json(rng: &mut Rng, n: usize) -> String {
        let vals: Vec<String> = (0..n).map(|_| format!("{:.4}", 0.15 * rng.normal())).collect();
        format!("[{}]", vals.join(","))
    }
    fn mat_json(rng: &mut Rng, r: usize, c: usize) -> String {
        let rows: Vec<String> = (0..r).map(|_| vec_json(rng, c)).collect();
        format!("[{}]", rows.join(","))
    }
    let blocks: Vec<String> = (0..n_blocks)
        .map(|_| {
            format!(
                r#"{{"w1": {}, "b1": {}, "u": {}, "w2": {}, "b2": {}}}"#,
                mat_json(rng, hidden, hidden),
                vec_json(rng, hidden),
                mat_json(rng, embed, hidden),
                mat_json(rng, hidden, hidden),
                vec_json(rng, hidden)
            )
        })
        .collect();
    format!(
        r#"{{"dim": {dim}, "hidden": {hidden}, "embed": {embed}, "n_blocks": {n_blocks},
            "params": {{"w_in": {}, "b_in": {}, "w_out": {}, "b_out": {}, "blocks": [{}]}}}}"#,
        mat_json(rng, dim, hidden),
        vec_json(rng, hidden),
        mat_json(rng, hidden, dim),
        vec_json(rng, dim),
        blocks.join(",")
    )
}

/// The PR-4 contended row body, factored so the single-model and 4-model
/// rows time the same request shape.
fn run_contended_single_model(coord: &Coordinator) {
    let kinds = [SolverKind::Tab(1), SolverKind::Tab(2), SolverKind::Dpm(1), SolverKind::Euler];
    let rxs: Vec<_> = (0..64)
        .map(|i| {
            // Distinct (solver, nfe) per submission: no admission merging,
            // so all 64 trajectories occupy their own flight slots and
            // contend on the (single) shard's scheduler state.
            let mut req = SampleRequest::new("gmm2d", kinds[i % kinds.len()], 8 + i / 4, 2);
            req.seed = i as u64;
            coord.submit(req)
        })
        .collect();
    for rx in rxs {
        black_box(rx.recv().unwrap().unwrap());
    }
}
