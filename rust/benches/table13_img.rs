//! Table 13: the ImageNet32 stand-in (img8, 64-dim): iPNDM / DDIM / tAB1-3.

use deis::diffusion::Sde;
use deis::exp::{print_table, run_solver, sweep_model, QualityEval};
use deis::solvers::SolverKind;
use deis::timegrid::GridKind;
use deis::util::bench::CsvSink;

fn main() {
    let sde = Sde::vp();
    let model = sweep_model("img8");
    let eval = QualityEval::new("img8", 4000);
    let nfes = [5usize, 10, 20, 50];
    let kinds = [
        SolverKind::Ipndm(3),
        SolverKind::Tab(0),
        SolverKind::Tab(1),
        SolverKind::Tab(2),
        SolverKind::Tab(3),
    ];
    let mut csv = CsvSink::new("table13.csv", "solver,nfe,swd1000");
    let mut rows = Vec::new();
    for kind in kinds {
        let mut vals = Vec::new();
        for &nfe in &nfes {
            let (x, _) = run_solver(&*model, &sde, kind, GridKind::Quadratic, 1e-3, nfe, 800, 7);
            let q = eval.score(&x).swd1000;
            csv.row(&format!("{},{nfe},{q:.3}", kind.name()));
            vals.push(q);
        }
        rows.push((kind.name(), vals));
    }
    print_table("Table 13: img8 / 64-dim (SWDx1000)",
        &nfes.iter().map(|n| format!("NFE {n}")).collect::<Vec<_>>(), &rows);
}
