//! Tables 4/5 (+ Table 14 with --seeds): PNDM vs iPNDM vs DDIM vs tAB1-3 on
//! the CIFAR10/CelebA stand-ins (gmm2d / spiral2d). PNDM only appears at
//! NFE >= 13 (its pseudo-RK warmup needs 12 evals, App. H.1).

use deis::diffusion::Sde;
use deis::exp::{print_table, run_solver, sweep_model, QualityEval};
use deis::solvers::SolverKind;
use deis::timegrid::GridKind;
use deis::util::bench::CsvSink;
use deis::util::cli::Args;

fn main() {
    let args = Args::parse_env();
    let seeds: Vec<u64> = (0..args.usize_or("seeds", 1) as u64).collect();
    let sde = Sde::vp();
    let nfes = [5usize, 10, 20, 50];
    let kinds = [
        SolverKind::Pndm,
        SolverKind::Ipndm(3),
        SolverKind::Tab(0),
        SolverKind::Tab(1),
        SolverKind::Tab(2),
        SolverKind::Tab(3),
    ];
    let mut csv = CsvSink::new("table45.csv", "dataset,solver,nfe,seed,swd1000");
    for dataset in ["gmm2d", "spiral2d"] {
        let model = sweep_model(dataset);
        let eval = QualityEval::new(dataset, 20_000);
        let mut rows = Vec::new();
        for kind in kinds {
            let mut vals = Vec::new();
            for &nfe in &nfes {
                if kind == SolverKind::Pndm && nfe < 13 {
                    vals.push(f64::NAN);
                    continue;
                }
                let mut acc = Vec::new();
                for &seed in &seeds {
                    let (x, _) = run_solver(&*model, &sde, kind, GridKind::Quadratic, 1e-3,
                        nfe, 4000, 7 + seed);
                    let q = eval.score(&x).swd1000;
                    csv.row(&format!("{dataset},{},{nfe},{seed},{q:.3}", kind.name()));
                    acc.push(q);
                }
                let mean = acc.iter().sum::<f64>() / acc.len() as f64;
                vals.push(mean);
                if seeds.len() > 1 {
                    let var = acc.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>()
                        / (acc.len() - 1) as f64;
                    println!("  {dataset} {} NFE{nfe}: {mean:.2} ± {:.2}", kind.name(),
                        var.sqrt());
                }
            }
            rows.push((kind.name(), vals));
        }
        print_table(
            &format!("Tables 4/5: PNDM family (SWDx1000, {dataset})"),
            &nfes.iter().map(|n| format!("NFE {n}")).collect::<Vec<_>>(),
            &rows,
        );
    }
    println!("\npaper shape: iPNDM works below 12 NFE where PNDM cannot; tAB3 best overall");
}
