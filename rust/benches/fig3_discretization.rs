//! Fig. 3a/3c: Delta_p (mean abs diff vs ground-truth ODE solution) for
//! Euler vs EI(score-param) vs EI(eps-param == DDIM) across step counts,
//! on the exact-score oracle — pure discretization error.

use deis::diffusion::Sde;
use deis::exp::{print_table, run_solver, sweep_model};
use deis::metrics::mean_abs_diff;
use deis::solvers::SolverKind;
use deis::timegrid::GridKind;
use deis::util::bench::CsvSink;

fn main() {
    let sde = Sde::vp();
    let oracle = sweep_model("gmm2d_oracle");
    let b = 64;
    let reference =
        run_solver(&*oracle, &sde, SolverKind::Tab(0), GridKind::Uniform, 1e-3, 2000, b, 3).0;
    let ns = [5usize, 10, 20, 50, 100, 200, 500];
    let kinds = [SolverKind::Euler, SolverKind::EiScore, SolverKind::Tab(0)];
    let mut csv = CsvSink::new("fig3_delta_p.csv", "n,euler,ei_score,ddim");
    let mut rows = Vec::new();
    for kind in kinds {
        let mut vals = Vec::new();
        for &n in &ns {
            let (x, _) = run_solver(&*oracle, &sde, kind, GridKind::Uniform, 1e-3, n, b, 3);
            vals.push(mean_abs_diff(&x, &reference));
        }
        rows.push((kind.name(), vals));
    }
    for (i, &n) in ns.iter().enumerate() {
        csv.row(&format!("{n},{:.6},{:.6},{:.6}", rows[0].1[i], rows[1].1[i], rows[2].1[i]));
    }
    print_table(
        "Fig 3a/3c: Delta_p vs N (uniform grid, exact score)",
        &ns.iter().map(|n| format!("N={n}")).collect::<Vec<_>>(),
        &rows,
    );
    // Paper shape assertions: EI-score worse than Euler at small N; eps-EI best.
    let (e, s, d) = (rows[0].1[1], rows[1].1[1], rows[2].1[1]);
    println!("\nshape @ N=10: euler {e:.4}  ei-score {s:.4}  ddim {d:.4}");
    assert!(s > e, "paper Fig 3a: EI with score param should be WORSE than Euler");
    assert!(d < e, "paper Fig 3c: EI with eps param should beat Euler");
}
