# Local entry points that match CI (.github/workflows/ci.yml) exactly —
# the toolchain is pinned by rust-toolchain.toml, so `make verify` passing
# here means the `verify` job passes there.

CARGO = cd rust && cargo

.PHONY: verify verify-full build test lint fmt clippy chaos serve-smoke loadgen-smoke router-smoke bench bench-quick bench-diff serve-demo loadgen-demo artifacts ci

## Tier-1 verify (ROADMAP): release build + full test suite.
verify:
	$(CARGO) build --release
	$(CARGO) test -q

## The whole local gate: tier-1 verify + the full CI lint job (clippy over
## every target — lib, tests, benches, examples — and the fmt check).
## Green here means both the `verify` and `lint` CI jobs pass.
verify-full: verify
	$(CARGO) clippy --all-targets -- -D warnings
	$(CARGO) fmt --check

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

## Lint job: formatting + clippy, warnings are errors.
lint: fmt clippy

## Chaos battery (EXPERIMENTS.md §Robustness): scripted fault injection
## against the live TCP service — eval panics, NaN outputs, stalls past
## deadlines — asserting fault containment, breaker open/recover and the
## 4-term lifecycle balance. Runs in release (timing-sensitive stalls) on
## top of the debug run `make test` already does.
chaos:
	$(CARGO) test --test chaos -q
	$(CARGO) test --release --test chaos -q

## Serving frontend smoke (EXPERIMENTS.md §Serving): 64 concurrent mixed
## clients (plain, JSON-sample, binary-frame, counted rejections) against
## the readiness-driven event loop, then the 4-term stats balance check.
## Release build: the burst is timing-sensitive under debug.
serve-smoke:
	$(CARGO) test --release --test serve_smoke -q

## Loadgen smoke (EXPERIMENTS.md §Load): deterministic open-loop plan per
## seed, then a short fixed-seed run against an in-process server asserting
## non-zero completions and EXACT client-vs-stats-wire reconciliation
## (global + per_model, deadline_hit/deadline_missed included). Release:
## the run replays a timed arrival schedule.
loadgen-smoke:
	$(CARGO) test --release --test loadgen_smoke -q

## Router smoke (EXPERIMENTS.md §Router): the multi-process sharding tier —
## rendezvous placement, bit-exact proxy parity (JSON + bin), stats/health
## fan-in sums, worker death mid-flight (error + re-home, counters balance),
## drain behind the router, and the --spawn-workers e2e path; then a short
## loadgen run THROUGH a 2-worker router with exact aggregated-stats
## reconciliation. Release: kill/drain timing is tight under debug.
router-smoke:
	$(CARGO) test --release --test router -q
	$(CARGO) run --release --example loadgen -- --router 2 --quick

fmt:
	$(CARGO) fmt --check

clippy:
	$(CARGO) clippy --all-targets -- -D warnings

## Full perf run: populates results/perf_hotpath.csv + BENCH_hotpath.json.
bench:
	$(CARGO) bench --bench perf_hotpath

## CI bench-smoke equivalent: every bench executes on a tiny budget.
bench-quick:
	$(CARGO) bench --bench perf_hotpath -- --quick

## §Perf backfill (EXPERIMENTS.md): download the parent commit's CI
## BENCH_hotpath artifact and print the row-by-row delta against the local
## BENCH_hotpath.json (run `make bench` first for numbers worth reading;
## needs `gh auth login`).
bench-diff:
	scripts/fetch_parent_bench.sh BENCH_parent.json
	python3 scripts/bench_diff.py BENCH_parent.json BENCH_hotpath.json

## Boot the sampling service on the analytic oracle (no artifacts needed)
## and show the step-level scheduler stats after a quick client burst:
##   printf '%s\n' '{"cmd":"stats"}' | nc 127.0.0.1 7878
serve-demo:
	$(CARGO) run --release -- serve --models gmm2d_oracle --workers 4

## Quick production-shaped load run against an in-process server (boots
## its own; pass --addr HOST:PORT after -- to target a live one). See
## EXPERIMENTS.md §Load for the full oldest-vs-EDF methodology.
loadgen-demo:
	$(CARGO) run --release --example loadgen -- --quick

## Build-time artifacts (JAX training + AOT lowering; needs the python env).
## Written to rust/artifacts: cargo runs tests/benches with cwd = rust/, and
## that is where the integration tests and the runtime default look.
artifacts:
	python3 python/compile/aot.py --out rust/artifacts
	python3 python/compile/fixtures.py --out rust/artifacts/fixtures

## Everything CI runs.
ci: verify lint chaos serve-smoke loadgen-smoke router-smoke bench-quick
