#!/usr/bin/env bash
# Download the parent commit's BENCH_hotpath CI artifact (uploaded by the
# bench-smoke job of .github/workflows/ci.yml) so scripts/bench_diff.py can
# print the row-by-row perf delta — the executable form of the
# EXPERIMENTS.md "§Perf backfill mechanism".
#
# Usage: fetch_parent_bench.sh [OUT.json]
#   OUT.json    where to write the parent snapshot (default BENCH_parent.json)
#
# Env:
#   PARENT_SHA  commit whose artifact to fetch (default: git rev-parse HEAD^)
#
# Needs the `gh` CLI with auth (locally: `gh auth login`; in CI: GH_TOKEN).
# Exits non-zero when no completed run/artifact exists for the parent —
# callers that treat the diff as best-effort should `|| true` it.
set -euo pipefail

OUT="${1:-BENCH_parent.json}"
PARENT="${PARENT_SHA:-$(git rev-parse HEAD^)}"

command -v gh >/dev/null || { echo "fetch_parent_bench: gh CLI not found" >&2; exit 1; }

echo "fetch_parent_bench: looking for a ci run of ${PARENT}" >&2
RUN_ID="$(gh run list --commit "$PARENT" --workflow ci \
    --json databaseId,status \
    --jq '[.[] | select(.status == "completed")][0].databaseId // empty')"
if [ -z "$RUN_ID" ]; then
    echo "fetch_parent_bench: no completed ci run for ${PARENT}" >&2
    exit 1
fi

TMP="$(mktemp -d)"
trap 'rm -rf "$TMP"' EXIT
gh run download "$RUN_ID" --name BENCH_hotpath --dir "$TMP"
cp "$TMP/BENCH_hotpath.json" "$OUT"
echo "fetch_parent_bench: wrote $OUT (run $RUN_ID, commit ${PARENT})" >&2
