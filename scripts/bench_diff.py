#!/usr/bin/env python3
"""Row-by-row delta table between two BENCH_hotpath.json files.

Usage: bench_diff.py PARENT.json CURRENT.json

The JSON shape is what rust/src/util/bench.rs::JsonSink writes:
    {"bench name": {"mean_us": X, "p50_us": X, "p99_us": X}, ...}

Prints one row per bench present in either file with the mean_us of both
sides and the relative delta (negative = faster now). Rows only in one
file are marked (new)/(gone). This is the executable half of the
EXPERIMENTS.md "§Perf backfill mechanism": diff the parent commit's CI
artifact against the current run. Numbers from `--quick` runs are
smoke-quality — use them to prove the mechanism, not to fill tables.

Exit code is always 0 when both files parse: a perf delta is a report,
not a gate.
"""

import json
import sys


def load(path):
    with open(path) as f:
        data = json.load(f)
    if not isinstance(data, dict):
        raise SystemExit(f"{path}: expected a JSON object of bench rows")
    return data


def fmt_us(v):
    return f"{v:10.1f}" if v is not None else " " * 10


def main():
    if len(sys.argv) != 3:
        raise SystemExit(__doc__.strip().splitlines()[2])
    old, new = load(sys.argv[1]), load(sys.argv[2])
    names = list(dict.fromkeys(list(old) + list(new)))  # stable union
    width = max((len(n) for n in names), default=5)
    print(f"{'bench':<{width}}  {'parent_us':>10}  {'current_us':>10}  {'delta':>8}")
    print("-" * (width + 34))
    for name in names:
        o = old.get(name, {}).get("mean_us")
        n = new.get(name, {}).get("mean_us")
        if o is None:
            note = "   (new)"
        elif n is None:
            note = "  (gone)"
        elif o > 0:
            note = f"{100.0 * (n - o) / o:+7.1f}%"
        else:
            note = "     n/a"
        print(f"{name:<{width}}  {fmt_us(o)}  {fmt_us(n)}  {note}")


if __name__ == "__main__":
    main()
